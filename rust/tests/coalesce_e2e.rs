//! End-to-end tests for executor-level op coalescing: ordering
//! checkers with merging forced on, the per-connection sweep
//! fairness cap, and WAL batch records surviving a crash.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use aggfunnels::service::{
    serve, BinRequest, BinResponse, ConnOpts, Item, PersistOpts, RegistryClient, ServeOpts,
    CreateSpec, DEFAULT_OBJECT,
};
use aggfunnels::util::json::Json;
use aggfunnels::verify::{encode_item, FifoChecker, LifoChecker};

/// Sum a counter across every shard of a cluster-stats aggregate.
fn shard_sum(agg: &Json, key: &str) -> u64 {
    agg.get("per_shard")
        .and_then(Json::as_arr)
        .map(|shards| shards.iter().filter_map(|s| s.get(key).and_then(Json::as_u64)).sum())
        .unwrap_or(0)
}

/// One pipelined batch of single-item enqueues (or pushes) carrying
/// `(producer, seq)`-encoded items — the shape the executor merges
/// into one batch insert.
fn insert_batch(op_push: bool, name: &str, producer: usize, seqs: std::ops::Range<u64>) -> Vec<BinRequest> {
    seqs.map(|seq| {
        let items = vec![Item::Int(encode_item(producer, seq))];
        if op_push {
            BinRequest::Push { name: name.to_string(), items }
        } else {
            BinRequest::Enqueue { name: name.to_string(), items }
        }
    })
    .collect()
}

#[test]
fn coalesced_queue_run_preserves_fifo_exactly() {
    // Many pipelined producers on one queue: every call_many batch is
    // a contiguous same-object run, so the executor merges it into
    // batch inserts — and the FIFO contract must hold regardless.
    let server = serve(&ServeOpts {
        conn: ConnOpts { coalesce: true, ..ConnOpts::default() },
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: u64 = 256;
    const BATCH: u64 = 32;

    {
        let c = RegistryClient::connect(&addr).unwrap();
        c.create_queue("jobs", &CreateSpec::backend("lcrq+elastic:fixed:2")).unwrap();
    }
    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect_binary(&addr).unwrap();
                let mut seq = 0u64;
                while seq < PER_PRODUCER {
                    let reqs = insert_batch(false, "jobs", p, seq..seq + BATCH);
                    for resp in c.call_many(&reqs).unwrap() {
                        assert!(matches!(resp, BinResponse::Enqueued(1)), "bad reply {resp:?}");
                    }
                    seq += BATCH;
                }
            })
        })
        .collect();
    for t in producers {
        t.join().unwrap();
    }

    // Two consumers drain it dry; each stream must be FIFO-consistent
    // per producer and the union the exact produced multiset.
    let consumers: Vec<_> = (0..2)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect(&addr).unwrap();
                let jobs = c.queue("jobs").unwrap();
                let mut stream = Vec::new();
                loop {
                    let got = jobs.dequeue_batch(16).unwrap();
                    if got.is_empty() {
                        break;
                    }
                    for item in got {
                        match item {
                            Item::Int(v) => stream.push(v),
                            other => panic!("unexpected item {other:?}"),
                        }
                    }
                }
                stream
            })
        })
        .collect();
    let mut checker = FifoChecker::new();
    for t in consumers {
        checker.add_stream(t.join().unwrap());
    }
    checker.check(PRODUCERS, PER_PRODUCER).unwrap();

    // The run must actually have exercised the merge path.
    let c = RegistryClient::connect(&addr).unwrap();
    let agg = c.cluster_stats().unwrap();
    assert!(shard_sum(&agg, "coalesce_merges") > 0, "pipelined runs must merge");
    assert!(
        shard_sum(&agg, "coalesced_ops") > shard_sum(&agg, "coalesce_merges"),
        "merged groups must average more than one op"
    );
    server.shutdown();
}

#[test]
fn coalesced_stack_two_phase_preserves_lifo_exactly() {
    // Two-phase: all pushes complete (merged into batch inserts),
    // then pops (merged into batch removes) — the LIFO checker's
    // contract.
    let server = serve(&ServeOpts {
        conn: ConnOpts { coalesce: true, ..ConnOpts::default() },
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    const PRODUCERS: usize = 3;
    const PER_PRODUCER: u64 = 192;
    const BATCH: u64 = 24;

    {
        let c = RegistryClient::connect(&addr).unwrap();
        c.create_stack("undo", &CreateSpec::backend("stack+elastic:fixed:2")).unwrap();
    }
    let pushers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect_binary(&addr).unwrap();
                let mut seq = 0u64;
                while seq < PER_PRODUCER {
                    let reqs = insert_batch(true, "undo", p, seq..seq + BATCH);
                    for resp in c.call_many(&reqs).unwrap() {
                        assert!(matches!(resp, BinResponse::Pushed(1)), "bad reply {resp:?}");
                    }
                    seq += BATCH;
                }
            })
        })
        .collect();
    for t in pushers {
        t.join().unwrap();
    }

    let poppers: Vec<_> = (0..2)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect(&addr).unwrap();
                let undo = c.stack("undo").unwrap();
                let mut stream = Vec::new();
                loop {
                    let got = undo.pop_batch(16).unwrap();
                    if got.is_empty() {
                        break;
                    }
                    for item in got {
                        match item {
                            Item::Int(v) => stream.push(v),
                            other => panic!("unexpected item {other:?}"),
                        }
                    }
                }
                stream
            })
        })
        .collect();
    let mut checker = LifoChecker::new();
    for t in poppers {
        checker.add_stream(t.join().unwrap());
    }
    checker.check(PRODUCERS, PER_PRODUCER).unwrap();

    let c = RegistryClient::connect(&addr).unwrap();
    let agg = c.cluster_stats().unwrap();
    assert!(shard_sum(&agg, "coalesce_merges") > 0, "pipelined runs must merge");
    server.shutdown();
}

#[test]
fn sweep_cap_keeps_interactive_latency_bounded_under_flood() {
    // One client floods deep pipelined take batches; another does
    // polite one-at-a-time takes. With a small `max_ops_per_sweep`
    // the flooder's queue is drained in slices, so the interactive
    // client is never stuck behind a whole megabatch.
    const CAP: usize = 4;
    const FLOOD_BATCH: usize = 512;
    let server = serve(&ServeOpts {
        conn: ConnOpts { max_ops_per_sweep: CAP, ..ConnOpts::default() },
        ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    let stop = Arc::new(AtomicBool::new(false));

    let flooder = {
        let addr = Arc::clone(&addr);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let c = RegistryClient::connect_binary(&addr).unwrap();
            let reqs: Vec<BinRequest> = (0..FLOOD_BATCH)
                .map(|_| BinRequest::Take {
                    name: DEFAULT_OBJECT.to_string(),
                    count: 1,
                    priority: false,
                })
                .collect();
            while !stop.load(Ordering::Relaxed) {
                for resp in c.call_many(&reqs).unwrap() {
                    assert!(matches!(resp, BinResponse::Start(_)));
                }
            }
        })
    };

    let c = RegistryClient::connect(&addr).unwrap();
    let tickets = c.counter(DEFAULT_OBJECT).unwrap();
    let mut worst = Duration::ZERO;
    for _ in 0..50 {
        let t0 = Instant::now();
        tickets.take(1).unwrap();
        worst = worst.max(t0.elapsed());
    }
    stop.store(true, Ordering::Relaxed);
    flooder.join().unwrap();

    // Generous bound: without the cap a sweep could hold the executor
    // for the flooder's whole backlog; with it, each interactive op
    // waits at most a few slices. A full second of headroom keeps
    // slow CI machines honest without hiding a real starvation bug.
    assert!(worst < Duration::from_secs(1), "interactive take stalled {worst:?} behind flood");
    let agg = c.cluster_stats().unwrap();
    assert!(
        shard_sum(&agg, "sweep_truncated") > 0,
        "the flooding connection must have hit the per-sweep cap"
    );
    server.shutdown();
}

#[test]
fn merged_batches_journal_one_record_and_recover_exactly() {
    // Sync-mode WAL + coalescing: a merged insert batch must append
    // ONE record (not one per op), and a crash must replay that
    // record back to the exact acked state.
    let dir = aggfunnels::util::scratch_dir("e2e-coalesce-wal");
    let dir_str = dir.to_string_lossy().into_owned();
    let serve_opts = || ServeOpts {
        persist: Some(PersistOpts::sync(dir_str.clone())),
        conn: ConnOpts { coalesce: true, ..ConnOpts::default() },
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    };
    let server = serve(&serve_opts()).unwrap();
    let addr = server.addr.to_string();

    const BATCHES: u64 = 16;
    const BATCH: u64 = 64;
    const OPS: u64 = BATCHES * BATCH;
    {
        let c = RegistryClient::connect(&addr).unwrap();
        c.create_queue("jobs", &CreateSpec::backend("lcrq+elastic:fixed:2")).unwrap();
        let bin = RegistryClient::connect_binary(&addr).unwrap();
        for b in 0..BATCHES {
            let reqs: Vec<BinRequest> = (0..BATCH)
                .map(|k| BinRequest::Enqueue {
                    name: "jobs".to_string(),
                    items: vec![Item::Int(b * BATCH + k)],
                })
                .collect();
            for resp in bin.call_many(&reqs).unwrap() {
                assert!(matches!(resp, BinResponse::Enqueued(1)), "bad reply {resp:?}");
            }
        }
        let agg = c.cluster_stats().unwrap();
        assert!(shard_sum(&agg, "coalesce_merges") > 0, "enqueue runs must merge");
        let records = shard_sum(&agg, "wal_records");
        assert!(records > 0, "sync mode must journal");
        assert!(
            records < OPS / 2,
            "{OPS} acked enqueues produced {records} WAL records — \
             merged batches should journal far fewer than one record per op"
        );
    }

    server.crash();

    let server = serve(&serve_opts()).unwrap();
    let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
    let jobs = c.queue("jobs").unwrap();
    let mut drained = Vec::new();
    loop {
        let got = jobs.dequeue_batch(128).unwrap();
        if got.is_empty() {
            break;
        }
        for item in got {
            match item {
                Item::Int(v) => drained.push(v),
                other => panic!("unexpected item {other:?}"),
            }
        }
    }
    let expected: Vec<u64> = (0..OPS).collect();
    assert_eq!(drained, expected, "replayed batch records must restore the exact FIFO state");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
