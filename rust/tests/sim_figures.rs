//! Shape-level acceptance tests for the regenerated figures: the
//! paper's qualitative claims must hold on reduced sweeps (the full
//! sweeps run under `cargo bench`; acceptance criteria are documented
//! in EXPERIMENTS.md).

use aggfunnels::bench::figures::{fig3, fig4_headline, fig6, SweepOpts};
use aggfunnels::bench::Row;

fn opts(grid: Vec<usize>) -> SweepOpts {
    SweepOpts { grid, horizon: 600_000, seed: 0x51AE }
}

fn value(rows: &[Row], fig: &str, series: &str, threads: usize) -> f64 {
    rows.iter()
        .find(|r| r.figure == fig && r.series == series && r.threads == threads)
        .unwrap_or_else(|| panic!("missing {fig}/{series}/{threads}"))
        .value
}

#[test]
fn fig3_shapes() {
    let rows = fig3(&opts(vec![2, 96]));
    // 3a: at 96 threads every aggfunnel variant beats hardware F&A.
    let hw = value(&rows, "3a", "hw-faa", 96);
    for m in [2, 4, 6, 8] {
        let agg = value(&rows, "3a", &format!("aggfunnel-{m}"), 96);
        assert!(agg > hw, "3a: aggfunnel-{m} ({agg:.1}) must beat hw ({hw:.1}) at 96 threads");
    }
    // 3b: fewer Aggregators -> larger batches (paper's observation).
    let b2 = value(&rows, "3b", "aggfunnel-2", 96);
    let b8 = value(&rows, "3b", "aggfunnel-8", 96);
    assert!(b2 > b8, "3b: m=2 batches ({b2:.2}) must exceed m=8 ({b8:.2})");
    // 3b: batches grow with threads.
    let b2_small = value(&rows, "3b", "aggfunnel-2", 2);
    assert!(b2 > b2_small, "3b: batches must grow with contention");
    // 3c: read-heavier workload still has aggfunnel ahead at scale,
    // but with lower absolute throughput than 3a for aggfunnel-6
    // (reads all hit Main).
    let agg_3c = value(&rows, "3c", "aggfunnel-6", 96);
    let hw_3c = value(&rows, "3c", "hw-faa", 96);
    assert!(agg_3c > hw_3c, "3c: aggfunnel must beat hw at scale");
}

#[test]
fn fig4_shapes() {
    let rows = fig4_headline(&opts(vec![2, 96]));
    let hw = value(&rows, "4a", "hw-faa", 96);
    let agg = value(&rows, "4a", "aggfunnel-6", 96);
    let comb = value(&rows, "4a", "combfunnel", 96);
    let rec = value(&rows, "4a", "rec-aggfunnel", 96);
    // Ordering at high thread counts: aggfunnel first; combfunnel and
    // hw below it; recursive between (paper: recursive did not beat
    // single-level up to 176 threads).
    assert!(agg > hw, "4a: aggfunnel ({agg:.1}) must beat hw ({hw:.1})");
    assert!(agg > comb, "4a: aggfunnel ({agg:.1}) must beat combfunnel ({comb:.1})");
    assert!(rec > hw, "4a: recursive ({rec:.1}) must beat hw ({hw:.1})");
    assert!(agg >= rec * 0.8, "4a: single-level should not lose badly to recursive");
    // At 2 threads hardware wins (funnel path overhead) — the paper's
    // low-thread-count observation.
    let hw2 = value(&rows, "4a", "hw-faa", 2);
    let comb2 = value(&rows, "4a", "combfunnel", 2);
    assert!(hw2 > comb2, "4a: hw must beat combfunnel at 2 threads");
    // 4b: fairness within [0,1]; aggfunnel fairness high at scale.
    let f_agg = value(&rows, "4b", "aggfunnel-6", 96);
    assert!(f_agg > 0.5 && f_agg <= 1.0, "4b: aggfunnel fairness {f_agg}");
}

#[test]
fn fig6_shapes() {
    let rows = fig6(&opts(vec![64]));
    for panel in ["6a", "6b", "6c"] {
        let hw = value(&rows, panel, "lcrq", 64);
        let agg = value(&rows, panel, "lcrq+aggfunnel", 64);
        let msq = value(&rows, panel, "msq", 64);
        assert!(
            agg > hw,
            "{panel}: lcrq+aggfunnel ({agg:.1}) must beat lcrq ({hw:.1}) at 64 threads"
        );
        assert!(hw > msq, "{panel}: lcrq ({hw:.1}) must beat msq ({msq:.1})");
    }
}
