//! Cross-implementation integration tests: every Fetch&Add
//! implementation must satisfy the same observable contract under real
//! concurrency (dense fetch-and-inc tickets, sum conservation with
//! mixed signs, sensible batch statistics).

use std::sync::Arc;

use aggfunnels::faa::{
    AggFunnel, AggFunnelConfig, Choose, CombiningFunnel, CombiningTree, FetchAddObject,
    HardwareFaa, RecursiveAggFunnel,
};

fn all_impls(p: usize) -> Vec<(&'static str, Arc<dyn FetchAddObject>)> {
    vec![
        ("hw", Arc::new(HardwareFaa::new(p))),
        ("aggfunnel-1", Arc::new(AggFunnel::with_config(AggFunnelConfig::new(p).with_aggregators(1)))),
        ("aggfunnel-6", Arc::new(AggFunnel::with_config(AggFunnelConfig::new(p).with_aggregators(6)))),
        (
            "aggfunnel-rand",
            Arc::new(AggFunnel::with_config(
                AggFunnelConfig::new(p).with_aggregators(3).with_choose(Choose::Random),
            )),
        ),
        (
            "aggfunnel-direct",
            Arc::new(AggFunnel::with_config(
                AggFunnelConfig::new(p).with_aggregators(2).with_direct_threads(1),
            )),
        ),
        (
            "aggfunnel-overflow",
            Arc::new(AggFunnel::with_config(
                AggFunnelConfig::new(p).with_aggregators(2).with_threshold(128),
            )),
        ),
        ("rec-aggfunnel", Arc::new(RecursiveAggFunnel::new(p, 4, 2))),
        ("combfunnel", Arc::new(CombiningFunnel::new(p))),
        ("flatcomb", Arc::new(CombiningTree::new(p))),
    ]
}

/// Fetch&Inc must hand out exactly {0, 1, ..., N-1}.
#[test]
fn dense_tickets_all_impls() {
    let p = 6;
    let per_thread = 2_000u64;
    for (name, faa) in all_impls(p) {
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&faa);
                std::thread::spawn(move || {
                    (0..per_thread).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let n = p as u64 * per_thread;
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "{name}: tickets not dense");
        assert_eq!(faa.read(0), n, "{name}: final value wrong");
    }
}

/// Mixed-sign concurrent adds conserve the total.
#[test]
fn sum_conservation_all_impls() {
    let p = 4;
    let per_thread = 3_000i64;
    for (name, faa) in all_impls(p) {
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&faa);
                std::thread::spawn(move || {
                    let mut sum = 0i64;
                    for i in 0..per_thread {
                        let d = match (tid + i as usize) % 3 {
                            0 => -7,
                            1 => 4,
                            _ => 9,
                        };
                        f.fetch_add(tid, d);
                        sum += d;
                    }
                    sum
                })
            })
            .collect();
        let expected: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(faa.read(0) as i64, expected, "{name}: sum not conserved");
    }
}

/// Interleaved reads never observe values outside the running range
/// under increment-only workloads (monotonicity of the object).
#[test]
fn reads_monotone_under_increments() {
    let p = 4;
    for (name, faa) in all_impls(p) {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let reader = {
            let f = Arc::clone(&faa);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut prev = 0u64;
                let mut reads = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let v = f.read(p - 1);
                    assert!(v >= prev, "read went backwards");
                    prev = v;
                    reads += 1;
                }
                reads
            })
        };
        let writers: Vec<_> = (0..p - 1)
            .map(|tid| {
                let f = Arc::clone(&faa);
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        f.fetch_add(tid, 2);
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let reads = reader.join().unwrap();
        assert!(reads > 0, "{name}: reader starved entirely");
        assert_eq!(faa.read(0), (p as u64 - 1) * 5_000 * 2, "{name}");
    }
}

/// Batch statistics are consistent: ops ≥ main F&As; combining
/// implementations batch under contention.
#[test]
fn batch_stats_consistent() {
    let p = 8;
    let faa = Arc::new(AggFunnel::with_config(AggFunnelConfig::new(p).with_aggregators(1)));
    let handles: Vec<_> = (0..p)
        .map(|tid| {
            let f = Arc::clone(&faa);
            std::thread::spawn(move || {
                for _ in 0..3_000 {
                    f.fetch_add(tid, 1);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let s = faa.batch_stats();
    assert_eq!(s.ops, p as u64 * 3_000);
    assert!(s.main_faas >= 1);
    assert!(s.main_faas <= s.ops);
}

/// CAS and Fetch&Or work through the funnel (RMWability) and interact
/// correctly with concurrent fetch_adds on the same object.
#[test]
fn rmw_operations_linearize_with_faas() {
    let p = 4;
    let faa = Arc::new(AggFunnel::new(p));
    // Writer threads add; one thread occasionally sets a high bit via
    // fetch_or; the bit must never be lost by fetch_adds.
    const FLAG: u64 = 1 << 40;
    let handles: Vec<_> = (0..p)
        .map(|tid| {
            let f = Arc::clone(&faa);
            std::thread::spawn(move || {
                if tid == 0 {
                    for _ in 0..100 {
                        f.fetch_or(tid, FLAG);
                        std::thread::yield_now();
                    }
                } else {
                    for _ in 0..2_000 {
                        f.fetch_add(tid, 1);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let v = faa.read(0);
    assert_eq!(v & FLAG, FLAG, "fetch_or bit lost");
    assert_eq!(v & 0xFFFF_FFFF, (p as u64 - 1) * 2_000, "adds lost");
}

/// The recording mode must not change results (spot check) and must
/// reconstruct histories whose batches tile the Aggregator exactly.
#[test]
fn recording_mode_reconstructs_history() {
    let p = 4;
    let faa = Arc::new(AggFunnel::with_config(
        AggFunnelConfig::new(p).with_aggregators(2).with_recording(),
    ));
    let handles: Vec<_> = (0..p)
        .map(|tid| {
            let f = Arc::clone(&faa);
            std::thread::spawn(move || {
                (0..1_000).map(|i| f.fetch_add(tid, 1 + (i % 7))).collect::<Vec<u64>>()
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let (history, recorded) = faa.extract_history();
    assert_eq!(history.ops(), 4_000);
    assert_eq!(recorded.len(), 4_000);
    // The history's batch sums must equal the final object value.
    let total: u64 = history.deltas.iter().sum();
    assert_eq!(faa.read(0), total);
}
