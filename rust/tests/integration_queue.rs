//! Queue integration tests: FIFO conformance for every queue variant
//! under real concurrency, ring-transition stress, and the
//! FifoChecker-based end-to-end validation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use aggfunnels::faa::WidthPolicy;
use aggfunnels::queue::{
    AggIndexFactory, CombIndexFactory, ConcurrentQueue, ElasticIndexFactory, HwIndexFactory,
    IndexFactory, Lcrq, MsQueue, Prq,
};
use aggfunnels::verify::{encode_item, FifoChecker};

fn all_queues(p: usize, ring_order: u32) -> Vec<(&'static str, Arc<dyn ConcurrentQueue>)> {
    vec![
        ("lcrq", Arc::new(Lcrq::with_ring_order(p, HwIndexFactory, ring_order))),
        (
            "lcrq+aggfunnel",
            Arc::new(Lcrq::with_ring_order(p, AggIndexFactory::new(p), ring_order)),
        ),
        (
            "lcrq+combfunnel",
            Arc::new(Lcrq::with_ring_order(p, CombIndexFactory { max_threads: p }, ring_order)),
        ),
        (
            "lcrq+elastic",
            Arc::new(Lcrq::with_ring_order(
                p,
                ElasticIndexFactory::with_policy(p, WidthPolicy::Fixed(2), 4),
                ring_order,
            )),
        ),
        ("lprq", Arc::new(Prq::with_ring_order(p, HwIndexFactory, ring_order))),
        (
            "prq+aggfunnel",
            Arc::new(Prq::with_ring_order(p, AggIndexFactory::new(p), ring_order)),
        ),
        (
            "prq+elastic",
            Arc::new(Prq::with_ring_order(
                p,
                ElasticIndexFactory::with_policy(p, WidthPolicy::Fixed(2), 4),
                ring_order,
            )),
        ),
        ("msq", Arc::new(MsQueue::new(p))),
    ]
}

/// Full produce/consume cycle with the verifier's FifoChecker.
fn fifo_run(name: &str, q: Arc<dyn ConcurrentQueue>, producers: usize, consumers: usize, per_producer: u64) {
    let total = producers as u64 * per_producer;
    let consumed = Arc::new(AtomicU64::new(0));
    let prod_handles: Vec<_> = (0..producers)
        .map(|tid| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for seq in 0..per_producer {
                    q.enqueue(tid, encode_item(tid, seq));
                }
            })
        })
        .collect();
    let cons_handles: Vec<_> = (0..consumers)
        .map(|c| {
            let q = Arc::clone(&q);
            let consumed = Arc::clone(&consumed);
            let tid = producers + c;
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Acquire) < total {
                    if let Some(v) = q.dequeue(tid) {
                        got.push(v);
                        consumed.fetch_add(1, Ordering::AcqRel);
                    } else {
                        std::thread::yield_now();
                    }
                }
                got
            })
        })
        .collect();
    for h in prod_handles {
        h.join().unwrap();
    }
    let mut checker = FifoChecker::new();
    for h in cons_handles {
        checker.add_stream(h.join().unwrap());
    }
    checker.check(producers, per_producer).unwrap_or_else(|e| panic!("{name}: {e}"));
    assert!(q.dequeue(0).is_none(), "{name}: queue not drained");
}

#[test]
fn fifo_all_queues_normal_rings() {
    for (name, q) in all_queues(8, 8) {
        fifo_run(name, q, 4, 4, 3_000);
    }
}

#[test]
fn fifo_all_queues_tiny_rings() {
    // Ring of 4 slots: constant ring close/link churn.
    for (name, q) in all_queues(8, 2) {
        fifo_run(name, q, 4, 4, 1_500);
    }
}

#[test]
fn unbalanced_producers_consumers() {
    for (name, q) in all_queues(8, 6) {
        fifo_run(&format!("{name}/1p7c"), Arc::clone(&q), 1, 7, 4_000);
    }
    for (name, q) in all_queues(8, 6) {
        fifo_run(&format!("{name}/7p1c"), Arc::clone(&q), 7, 1, 1_000);
    }
}

#[test]
fn elastic_index_fifo_holds_while_controller_resizes() {
    // FIFO conformance for LCRQ+elastic while a controller thread
    // walks the factory's live ring indices (the service's resize
    // controller, in miniature), across ring-transition churn.
    let p = 8;
    let factory = ElasticIndexFactory::with_policy(p, WidthPolicy::Fixed(2), 6);
    let handle = factory.clone();
    let q: Arc<dyn ConcurrentQueue> = Arc::new(Lcrq::with_ring_order(p, factory, 3));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let controller = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = 1usize;
            while !stop.load(Ordering::Relaxed) {
                handle.resize(w);
                w = w % 6 + 1;
                std::thread::yield_now();
            }
            handle.batch_stats()
        })
    };
    fifo_run("lcrq+elastic/resizing", Arc::clone(&q), 4, 4, 2_000);
    stop.store(true, Ordering::Relaxed);
    let stats = controller.join().unwrap();
    assert!(stats.ops >= 2 * 4 * 2_000, "every enqueue and dequeue hits an index F&A");
}

#[test]
fn elastic_prq_fifo_holds_while_controller_resizes() {
    // The PRQ twin of the LCRQ test above: single-word-CAS rings
    // whose Head/Tail ride elastic funnels, resized mid-load by a
    // controller walking the factory's live cells.
    let p = 8;
    let factory = ElasticIndexFactory::with_policy(p, WidthPolicy::Fixed(2), 6);
    let handle = factory.clone();
    let q: Arc<dyn ConcurrentQueue> = Arc::new(Prq::with_ring_order(p, factory, 3));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let controller = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = 1usize;
            while !stop.load(Ordering::Relaxed) {
                handle.resize(w);
                w = w % 6 + 1;
                std::thread::yield_now();
            }
            handle.batch_stats()
        })
    };
    fifo_run("prq+elastic/resizing", Arc::clone(&q), 4, 4, 2_000);
    stop.store(true, Ordering::Relaxed);
    let stats = controller.join().unwrap();
    assert!(stats.ops >= 2 * 4 * 2_000, "every enqueue and dequeue hits an index F&A");
}

#[test]
fn emptiness_is_linearizable_single_consumer() {
    // With one consumer and producers that stop, the consumer must see
    // exactly the produced items then persistent emptiness.
    let q: Arc<dyn ConcurrentQueue> = Arc::new(Lcrq::with_ring_order(3, HwIndexFactory, 4));
    let producer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            for seq in 0..10_000u64 {
                q.enqueue(0, encode_item(0, seq));
            }
        })
    };
    let mut got = 0u64;
    while got < 10_000 {
        if let Some(v) = q.dequeue(1) {
            assert_eq!(v, encode_item(0, got), "out of order");
            got += 1;
        }
    }
    producer.join().unwrap();
    assert!(q.dequeue(1).is_none());
    assert!(q.dequeue(1).is_none());
}

#[test]
fn alternating_enq_deq_keeps_rings_bounded() {
    // enq/deq pairs never grow the queue: even with a tiny ring the
    // chain must stay short (the head ring gets reused or replaced,
    // but the queue never accumulates items).
    let q = Arc::new(Lcrq::with_ring_order(4, HwIndexFactory, 3));
    let handles: Vec<_> = (0..4)
        .map(|tid| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..20_000u64 {
                    q.enqueue(tid, encode_item(tid, i));
                    let _ = q.dequeue(tid);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Drain whatever is left (≤ p items in flight).
    let mut leftovers = 0;
    while q.dequeue(0).is_some() {
        leftovers += 1;
    }
    assert!(leftovers <= 4, "pairs workload leaked {leftovers} items");
}
