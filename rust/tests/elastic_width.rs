//! Adaptive-width subsystem integration tests: linearizability while a
//! background controller resizes the funnel, and `BatchStats`
//! accounting invariants under elasticity (hand-rolled property
//! tests, satellite of the adaptive-width PR).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use aggfunnels::faa::{
    AimdParams, ElasticAggFunnel, ElasticConfig, FetchAddObject, WidthPolicy,
};
use aggfunnels::util::prop::{run as prop_run, PropConfig};
use aggfunnels::util::rng::Rng;
use aggfunnels::verify::{verify_history_against, OracleBackend};
use aggfunnels::{prop_assert, prop_assert_eq};

/// The PR's acceptance criterion: a recording-mode elastic funnel
/// stays linearizable (every return value matches the oracle, sums
/// conserve) while a background thread drives `WidthPolicy::Aimd`
/// resizes against live contention windows.
#[test]
fn aimd_resizes_under_load_stay_linearizable() {
    let p = 6;
    let ops_per_thread = 4_000;
    let f = Arc::new(ElasticAggFunnel::with_config(
        ElasticConfig::new(p).with_max_width(8).with_recording(),
    ));
    let stop = Arc::new(AtomicBool::new(false));

    // Background controller: poll the AIMD policy continuously, and
    // interleave forced resizes so the run provably crosses widths
    // even if the policy settles early.
    let controller = {
        let f = Arc::clone(&f);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let aimd = WidthPolicy::Aimd(AimdParams::default());
            let mut tick = 0usize;
            let mut widths_seen = std::collections::BTreeSet::new();
            while !stop.load(Ordering::Relaxed) {
                widths_seen.insert(f.poll_policy(&aimd));
                if tick % 7 == 3 {
                    f.resize(1 + tick % 8);
                }
                tick += 1;
                std::thread::yield_now();
            }
            (tick, widths_seen)
        })
    };

    let handles: Vec<_> = (0..p)
        .map(|tid| {
            let f = Arc::clone(&f);
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xE1A5 ^ (tid as u64) << 8);
                let mut sum = 0i64;
                for _ in 0..ops_per_thread {
                    let mag = rng.range_inclusive(1, 100) as i64;
                    let delta = if rng.chance(0.5) { mag } else { -mag };
                    f.fetch_add(tid, delta);
                    sum += delta;
                }
                sum
            })
        })
        .collect();
    let expected: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    stop.store(true, Ordering::Relaxed);
    let (ticks, widths_seen) = controller.join().unwrap();

    assert!(ticks > 0, "controller never ran");
    assert!(widths_seen.len() > 1, "run never actually changed width: {widths_seen:?}");
    assert!(f.resizes() > 0);

    // Invariant 3.3: sum conservation.
    assert_eq!(f.read(0) as i64, expected);

    // Lemma 3.4 via the existing history checker: every recorded
    // return value must match the linearization oracle.
    let (history, recorded) = f.extract_history();
    assert_eq!(history.ops(), p * ops_per_thread);
    verify_history_against(&history, &recorded, &OracleBackend::Cpu)
        .expect("elastic run not linearizable");
}

/// Property (satellite): `BatchStats` accounting under the elastic
/// funnel — `ops >= main_faas` always, and the average batch size
/// never regresses below 1.0 when any combining occurred, across
/// random thread counts, capacities, policies and resize schedules.
#[test]
fn prop_elastic_batch_stats_accounting() {
    prop_run(
        "elastic_batch_stats",
        PropConfig { cases: 10, seed: 0xE1A5_71C5, max_size: 8 },
        |c| {
            let p = 1 + c.rng.below(6) as usize;
            let max_width = 1 + c.rng.below(8) as usize;
            let start_width = 1 + c.rng.below(max_width as u64) as usize;
            let per_thread = 300 + c.rng.below(700);
            let f = Arc::new(ElasticAggFunnel::with_config(
                ElasticConfig::new(p)
                    .with_max_width(max_width)
                    .with_policy(WidthPolicy::Fixed(start_width)),
            ));
            let resize_seed = c.rng.next_u64();
            let handles: Vec<_> = (0..p)
                .map(|tid| {
                    let f = Arc::clone(&f);
                    std::thread::spawn(move || {
                        let mut rng = Rng::new(resize_seed ^ tid as u64);
                        for i in 0..per_thread {
                            // Thread 0 churns the width mid-run.
                            if tid == 0 && i % 50 == 0 {
                                f.resize(1 + (rng.next_u64() % 8) as usize);
                            }
                            f.fetch_add(tid, rng.range_inclusive(1, 100) as i64);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let stats = f.batch_stats();
            prop_assert_eq!(stats.ops, p as u64 * per_thread);
            prop_assert!(
                stats.ops >= stats.main_faas,
                "ops {} < main_faas {}",
                stats.ops,
                stats.main_faas
            );
            prop_assert!(
                stats.single_op_batches <= stats.main_faas,
                "single-op batches {} exceed batches {}",
                stats.single_op_batches,
                stats.main_faas
            );
            if stats.combining_occurred() {
                prop_assert!(
                    stats.avg_batch_size() >= 1.0,
                    "avg batch {} below 1.0 despite combining",
                    stats.avg_batch_size()
                );
            }
            Ok(())
        },
    );
}

/// Growth re-spreads load: after widening, new Aggregator slots see
/// traffic (observable as the funnel still dispensing dense tickets
/// and the active width reporting the grown value).
#[test]
fn grow_and_shrink_roundtrip_keeps_tickets_dense() {
    let p = 4;
    let f = Arc::new(ElasticAggFunnel::with_config(
        ElasticConfig::new(p).with_max_width(8).with_policy(WidthPolicy::Fixed(2)),
    ));
    let phases = [2usize, 8, 1, 5];
    let mut all = Vec::new();
    for (phase, &w) in phases.iter().enumerate() {
        f.resize(w);
        assert_eq!(f.active_width(), w);
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..500).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), (phase + 1) * p * 500);
    }
    all.sort_unstable();
    let n = all.len() as u64;
    assert_eq!(all, (0..n).collect::<Vec<_>>(), "tickets not dense across width phases");
    assert_eq!(f.read(0), n);
}
