//! End-to-end tests of the registry service over real TCP: concurrent
//! clients, multiple named objects, priority requests, error handling,
//! and shutdown.

use std::sync::Arc;

use aggfunnels::config::ObjectManifest;
use aggfunnels::service::{
    serve, CreateSpec, ErrorCode, RegistryClient, ServeOpts, ServiceError, DEFAULT_OBJECT,
};
use aggfunnels::util::json::Json;

fn start(workers: usize) -> aggfunnels::service::ServerHandle {
    serve(&ServeOpts::fixed("127.0.0.1:0", workers, 2)).unwrap()
}

fn code_of(err: &anyhow::Error) -> Option<ErrorCode> {
    err.downcast_ref::<ServiceError>().map(|se| se.code)
}

#[test]
fn many_clients_disjoint_coverage() {
    // 6 concurrent clients; the event core multiplexes them over the
    // executor pool regardless of the worker count.
    let server = start(4);
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let tickets =
                    RegistryClient::connect(&addr).unwrap().counter(DEFAULT_OBJECT).unwrap();
                let mut out = Vec::new();
                for k in 0..200u64 {
                    let count = 1 + (i as u64 + k) % 5;
                    let start = if k % 10 == 0 {
                        tickets.take_priority(count).unwrap()
                    } else {
                        tickets.take(count).unwrap()
                    };
                    out.push((start, count));
                }
                out
            })
        })
        .collect();
    let mut ranges: Vec<(u64, u64)> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap in dispensed tickets");
        expect = s + c;
    }
    let c = RegistryClient::connect(&addr).unwrap();
    assert_eq!(c.counter(DEFAULT_OBJECT).unwrap().read().unwrap(), expect);
    server.shutdown();
}

#[test]
fn stats_reflect_traffic() {
    let server = start(2);
    let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
    let tickets = c.counter(DEFAULT_OBJECT).unwrap();
    for _ in 0..5 {
        tickets.take(1).unwrap();
    }
    tickets.take_priority(1).unwrap();
    tickets.read().unwrap();
    let stats = tickets.stats().unwrap();
    assert!(stats.get("take").and_then(Json::as_u64).unwrap() >= 5);
    assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
    assert!(stats.get("read").and_then(Json::as_u64).unwrap() >= 1);
    server.shutdown();
}

#[test]
fn adaptive_service_survives_burst_and_reports_width() {
    // An AIMD-managed server under a client burst: tickets must stay
    // disjoint and dense, and stats must expose the live width.
    let server = serve(&ServeOpts {
        policy: aggfunnels::faa::WidthPolicy::Aimd(Default::default()),
        max_aggregators: 8,
        resize_interval_ms: 5,
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let tickets =
                    RegistryClient::connect(&addr).unwrap().counter(DEFAULT_OBJECT).unwrap();
                let mut out = Vec::new();
                for _ in 0..300u64 {
                    out.push((tickets.take(1).unwrap(), 1u64));
                }
                out
            })
        })
        .collect();
    let mut ranges: Vec<(u64, u64)> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap while resizing");
        expect = s + c;
    }
    let c = RegistryClient::connect(&addr).unwrap();
    let stats = c.object_stats(DEFAULT_OBJECT).unwrap();
    let width = stats.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=8).contains(&width), "width {width} out of range");
    assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("aimd"));
    server.shutdown();
}

#[test]
fn two_objects_served_concurrently_with_independent_stats() {
    // The registry acceptance path: one named counter and one LCRQ
    // queue with an elastic funnel index, created at boot from a
    // manifest, driven concurrently over real TCP. Counter ranges must
    // stay dense, the queue must neither lose nor duplicate items, and
    // per-object `stats` must report independent width/contention
    // counters.
    let clients = 4;
    let per_client = 250u64;
    let server = serve(&ServeOpts {
        resize_interval_ms: 5,
        objects: vec![ObjectManifest::new("jobs", "queue", "lcrq+elastic:aimd")],
        ..ServeOpts::fixed("127.0.0.1:0", clients + 1, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..clients as u64)
        .map(|i| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect(&addr).unwrap();
                let tickets = c.counter(DEFAULT_OBJECT).unwrap();
                let jobs = c.queue("jobs").unwrap();
                let mut ranges = Vec::new();
                let mut got = Vec::new();
                for k in 0..per_client {
                    let count = 1 + k % 3;
                    let start = if k % 9 == 0 {
                        tickets.take_priority(count).unwrap()
                    } else {
                        tickets.take(count).unwrap()
                    };
                    ranges.push((start, count));
                    jobs.enqueue((i << 32) | k).unwrap();
                    if let Some(item) = jobs.dequeue().unwrap() {
                        got.push(item);
                    }
                }
                (ranges, got)
            })
        })
        .collect();
    let mut ranges = Vec::new();
    let mut consumed = Vec::new();
    for h in handles {
        let (r, g) = h.join().unwrap();
        ranges.extend(r);
        consumed.extend(g);
    }
    // Counter: dense disjoint ranges despite queue traffic.
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap in counter ranges");
        expect = s + c;
    }
    // Queue: drain the stragglers, then the multiset must be exact.
    let c = RegistryClient::connect(&addr).unwrap();
    let jobs = c.queue("jobs").unwrap();
    while let Some(item) = jobs.dequeue().unwrap() {
        consumed.push(item);
    }
    consumed.sort_unstable();
    let mut expected: Vec<u64> = (0..clients as u64)
        .flat_map(|i| (0..per_client).map(move |k| (i << 32) | k))
        .collect();
    expected.sort_unstable();
    assert_eq!(consumed, expected, "queue lost or duplicated items");

    // Independent per-object stats.
    let tickets = c.object_stats(DEFAULT_OBJECT).unwrap();
    let jobs = c.object_stats("jobs").unwrap();
    assert_eq!(tickets.get("kind").and_then(Json::as_str), Some("counter"));
    assert_eq!(jobs.get("kind").and_then(Json::as_str), Some("queue"));
    let takes = tickets.get("take").and_then(Json::as_u64).unwrap()
        + tickets.get("take_priority").and_then(Json::as_u64).unwrap();
    assert_eq!(takes, clients as u64 * per_client);
    assert!(tickets.get("enqueue").is_none(), "no queue traffic on the counter");
    assert!(jobs.get("enqueue").and_then(Json::as_u64).unwrap() >= clients as u64 * per_client);
    assert!(jobs.get("take").is_none(), "no counter traffic on the queue");
    // Both objects expose their own (elastic) width and contention
    // counters, sized by their own capacity.
    let t_width = tickets.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=2).contains(&t_width), "counter width {t_width}");
    let j_width = jobs.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=12).contains(&j_width), "queue index width {j_width}");
    assert!(jobs.get("index_cells").and_then(Json::as_u64).unwrap() >= 2);
    assert!(jobs.get("main_faas").and_then(Json::as_u64).unwrap() > 0);
    let t_ops = tickets.get("batched_ops").and_then(Json::as_u64).unwrap();
    let j_ops = jobs.get("batched_ops").and_then(Json::as_u64).unwrap();
    assert!(t_ops > 0 && j_ops > 0, "both funnels saw traffic");
    server.shutdown();
}

#[test]
fn four_shards_serve_independent_objects_with_global_view() {
    // The sharding acceptance path: a 4-shard server with a mixed
    // counter+queue namespace created *through* different shards.
    // Every object must be independently served (dense counter
    // ranges, exact queue multisets per object), while `list` and the
    // cluster aggregate see all of them regardless of shard.
    let clients = 4;
    let per_client = 150u64;
    let shards = 4;
    // These four names hash to four distinct shards (and to both
    // shards at shards = 2) — the spread is asserted below.
    let counters = ["orders", "users"];
    let queues = ["jobs", "mail"];
    let server = serve(&ServeOpts {
        resize_interval_ms: 5,
        ..ServeOpts::sharded("127.0.0.1:0", shards, clients + 1, 2)
    })
    .unwrap();
    assert_eq!(server.shard_ports().len(), shards);
    let addr = Arc::new(server.addr.to_string());

    // Create the namespace through a routing client; the objects land
    // on their hash shards.
    {
        let c = RegistryClient::connect(&addr).unwrap();
        assert_eq!(c.shards(), shards, "client learned the shard map");
        for name in counters {
            c.create_counter(name, &CreateSpec::backend("elastic:fixed:2")).unwrap();
        }
        for name in queues {
            c.create_queue(name, &CreateSpec::backend("lcrq+elastic:fixed:2")).unwrap();
        }
        let shard_spread: std::collections::BTreeSet<usize> = counters
            .iter()
            .chain(queues.iter())
            .map(|n| c.shard_for(n))
            .collect();
        assert_eq!(shard_spread.len(), shards, "namespace must cover every shard");
    }

    let handles: Vec<_> = (0..clients as u64)
        .map(|i| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect(&addr).unwrap();
                let counter = c.counter(["orders", "users"][(i % 2) as usize]).unwrap();
                let queue = c.queue(["jobs", "mail"][(i % 2) as usize]).unwrap();
                let mut ranges = Vec::new();
                let mut got = Vec::new();
                for k in 0..per_client {
                    let count = 1 + k % 3;
                    let start = if k % 9 == 0 {
                        counter.take_priority(count).unwrap()
                    } else {
                        counter.take(count).unwrap()
                    };
                    ranges.push((start, count));
                    queue.enqueue((i << 32) | k).unwrap();
                    if let Some(item) = queue.dequeue().unwrap() {
                        got.push(item);
                    }
                }
                (i, ranges, got)
            })
        })
        .collect();
    // Per-object result pools: clients i and i+2 share object pair
    // i % 2, so ranges and items merge per object.
    let mut ranges_by_counter: std::collections::BTreeMap<&str, Vec<(u64, u64)>> =
        Default::default();
    let mut consumed_by_queue: std::collections::BTreeMap<&str, Vec<u64>> = Default::default();
    let mut expected_by_queue: std::collections::BTreeMap<&str, Vec<u64>> = Default::default();
    for h in handles {
        let (i, ranges, got) = h.join().unwrap();
        ranges_by_counter.entry(counters[(i % 2) as usize]).or_default().extend(ranges);
        consumed_by_queue.entry(queues[(i % 2) as usize]).or_default().extend(got);
        expected_by_queue
            .entry(queues[(i % 2) as usize])
            .or_default()
            .extend((0..per_client).map(|k| (i << 32) | k));
    }
    let c = RegistryClient::connect(&addr).unwrap();
    // Counters: each object's ranges tile [0, its own total) densely —
    // objects on different shards never bleed into each other.
    for (name, mut ranges) in ranges_by_counter {
        ranges.sort_unstable();
        let mut expect = 0;
        for (s, n) in ranges {
            assert_eq!(s, expect, "{name}: gap or overlap in counter ranges");
            expect = s + n;
        }
        assert_eq!(
            c.counter(name).unwrap().read().unwrap(),
            expect,
            "{name}: final counter value"
        );
    }
    // Queues: drain stragglers, then each multiset must be exact.
    for (name, consumed) in &mut consumed_by_queue {
        let q = c.queue(name).unwrap();
        while let Some(item) = q.dequeue().unwrap() {
            consumed.push(item);
        }
        consumed.sort_unstable();
        let expected = expected_by_queue.get_mut(name).unwrap();
        expected.sort_unstable();
        assert_eq!(consumed, expected, "{name}: queue lost or duplicated items");
    }

    // Cross-shard view: `list` merges every shard, sorted.
    let listed = c.list().unwrap();
    let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
    assert_eq!(names, vec!["jobs", "mail", "orders", "tickets", "users"]);
    // The cluster aggregate counts every object and shard.
    let agg = c.cluster_stats().unwrap();
    assert_eq!(agg.get("shards").and_then(Json::as_u64), Some(shards as u64));
    assert_eq!(agg.get("objects").and_then(Json::as_u64), Some(5));
    let takes = agg
        .get("totals")
        .and_then(|t| t.get("take"))
        .and_then(Json::as_u64)
        .unwrap_or(0)
        + agg
            .get("totals")
            .and_then(|t| t.get("take_priority"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
    assert_eq!(takes, clients as u64 * per_client, "aggregate sees all counter traffic");
    // Per-object stats still resolve through the owning shard.
    let orders = c.object_stats("orders").unwrap();
    assert_eq!(orders.get("kind").and_then(Json::as_str), Some("counter"));
    assert!(orders.get("shard").and_then(Json::as_u64).is_some());
    server.shutdown();
}

#[test]
fn single_shard_server_is_wire_compatible_with_pr3_clients() {
    // A raw pre-shard client: no handshake, first line read is the
    // first response. Against `shards = 1` the server must not greet.
    use std::io::{BufRead, Write};
    let server = start(2);
    let conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(conn);
    writer.write_all(b"{\"op\":\"take\",\"count\":2}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{line}");
    assert_eq!(resp.get("start").and_then(Json::as_u64), Some(0));
    server.shutdown();
}

#[test]
fn concurrent_create_delete_over_the_wire() {
    // Registry race, end to end: two connections fight over one name
    // with create/delete; every response must be a clean ok or error
    // line and the server must stay serviceable.
    let server = start(3);
    let addr = Arc::new(server.addr.to_string());
    let spinners: Vec<_> = (0..2)
        .map(|t| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let c = RegistryClient::connect(&addr).unwrap();
                let mut ok = 0u64;
                for i in 0..100 {
                    let r = if (t + i) % 2 == 0 {
                        c.create("contested", "counter", &CreateSpec::backend("elastic:fixed:1"))
                    } else {
                        c.delete("contested")
                    };
                    if r.is_ok() {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let wins: u64 = spinners.into_iter().map(|s| s.join().unwrap()).sum();
    assert!(wins > 0, "at least some ops must win the race");
    let c = RegistryClient::connect(&addr).unwrap();
    assert_eq!(
        c.counter(DEFAULT_OBJECT).unwrap().take(1).unwrap(),
        0,
        "server survived the churn"
    );
    server.shutdown();
}

#[test]
fn delete_during_enqueue_storm_is_clean() {
    // One connection hammers enqueues while another deletes the
    // queue. The enqueuer must see only clean responses (ok until the
    // delete lands, typed no_such_object errors after) and the server
    // must keep serving both connections.
    let server = start(3);
    let addr = server.addr.to_string();
    let victim = RegistryClient::connect(&addr).unwrap();
    let doomed = victim.create_queue("doomed", &CreateSpec::backend("lcrq+elastic:fixed:2")).unwrap();
    // Resolve the storm connection's handle before the delete can
    // land, so the lookup itself never races the removal.
    let storm_q = RegistryClient::connect(&addr).unwrap().queue("doomed").unwrap();
    let writer = {
        std::thread::spawn(move || {
            let q = storm_q;
            let mut sent = 0u64;
            let mut refused = 0u64;
            for i in 0..2000u64 {
                match q.enqueue(i) {
                    Ok(()) => {
                        assert_eq!(refused, 0, "enqueue succeeded after a 'no object' error");
                        sent += 1;
                    }
                    Err(e) => {
                        assert_eq!(
                            code_of(&e),
                            Some(ErrorCode::NoSuchObject),
                            "unexpected error mid-storm: {e}"
                        );
                        assert!(e.to_string().contains("no object"), "message text kept: {e}");
                        refused += 1;
                    }
                }
            }
            (sent, refused)
        })
    };
    // Let the storm get going, then yank the object out from under it.
    std::thread::sleep(std::time::Duration::from_millis(5));
    victim.delete("doomed").unwrap();
    let (sent, refused) = writer.join().unwrap();
    assert_eq!(sent + refused, 2000, "every request got a response");
    assert!(doomed.dequeue().is_err(), "object is gone");
    // The victim's connection still works.
    assert_eq!(victim.counter(DEFAULT_OBJECT).unwrap().take(1).unwrap(), 0);
    server.shutdown();
}

#[test]
fn shutdown_is_prompt_under_concurrent_connects() {
    // The old nudge-based shutdown could hang if its wake-up
    // connection was consumed as a client; the polling cores must shut
    // down promptly even while new clients keep arriving.
    for _ in 0..5 {
        let server = start(2);
        let addr = server.addr.to_string();
        let spam = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // Connections racing the stop flag; errors are fine.
                for _ in 0..20 {
                    let _ = std::net::TcpStream::connect(&addr);
                }
            })
        };
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "shutdown took {:?}",
            t0.elapsed()
        );
        spam.join().unwrap();
    }
}

#[test]
fn malformed_requests_do_not_kill_connection() {
    use std::io::{BufRead, Write};
    let server = start(2);
    let conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(conn);
    for bad in ["not json", "{}", "{\"op\":42}", "{\"op\":\"bogus\"}"] {
        writer.write_all(bad.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
        assert_eq!(
            resp.get("code").and_then(Json::as_str),
            Some("protocol"),
            "malformed requests carry the protocol code: {bad}"
        );
    }
    // Still serviceable afterwards.
    writer.write_all(b"{\"op\":\"take\",\"count\":2}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();
}
