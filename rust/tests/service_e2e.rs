//! End-to-end tests of the registry service over real TCP: concurrent
//! clients, multiple named objects, priority requests, error handling,
//! and shutdown.

use std::sync::Arc;

use aggfunnels::config::ObjectManifest;
use aggfunnels::service::{serve, ServeOpts, TicketClient};
use aggfunnels::util::json::Json;

fn start(workers: usize) -> aggfunnels::service::ServerHandle {
    serve(&ServeOpts::fixed("127.0.0.1:0", workers, 2)).unwrap()
}

#[test]
fn many_clients_disjoint_coverage() {
    // 7 connection slots: 6 concurrent clients plus the final reader.
    let server = start(7);
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut c = TicketClient::connect(&addr).unwrap();
                let mut out = Vec::new();
                for k in 0..200u64 {
                    let count = 1 + (i as u64 + k) % 5;
                    let start = c.take(count, k % 10 == 0).unwrap();
                    out.push((start, count));
                }
                out
            })
        })
        .collect();
    let mut ranges: Vec<(u64, u64)> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap in dispensed tickets");
        expect = s + c;
    }
    let mut c = TicketClient::connect(&addr).unwrap();
    assert_eq!(c.read().unwrap(), expect);
    server.shutdown();
}

#[test]
fn stats_reflect_traffic() {
    let server = start(2);
    let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
    for _ in 0..5 {
        c.take(1, false).unwrap();
    }
    c.take(1, true).unwrap();
    c.read().unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.get("take").and_then(Json::as_u64).unwrap() >= 5);
    assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
    assert!(stats.get("read").and_then(Json::as_u64).unwrap() >= 1);
    server.shutdown();
}

#[test]
fn adaptive_service_survives_burst_and_reports_width() {
    // An AIMD-managed server under a client burst: tickets must stay
    // disjoint and dense, and stats must expose the live width.
    let server = serve(&ServeOpts {
        policy: aggfunnels::faa::WidthPolicy::Aimd(Default::default()),
        max_aggregators: 8,
        resize_interval_ms: 5,
        // One spare slot: the post-burst stats probe may connect
        // before the burst clients' leases are released.
        ..ServeOpts::fixed("127.0.0.1:0", 5, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut c = TicketClient::connect(&addr).unwrap();
                let mut out = Vec::new();
                for _ in 0..300u64 {
                    out.push((c.take(1, false).unwrap(), 1u64));
                }
                out
            })
        })
        .collect();
    let mut ranges: Vec<(u64, u64)> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap while resizing");
        expect = s + c;
    }
    let mut c = TicketClient::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    let width = stats.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=8).contains(&width), "width {width} out of range");
    assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("aimd"));
    server.shutdown();
}

#[test]
fn two_objects_served_concurrently_with_independent_stats() {
    // The registry acceptance path: one named counter and one LCRQ
    // queue with an elastic funnel index, created at boot from a
    // manifest, driven concurrently over real TCP. Counter ranges must
    // stay dense, the queue must neither lose nor duplicate items, and
    // per-object `stats` must report independent width/contention
    // counters.
    let clients = 4;
    let per_client = 250u64;
    let server = serve(&ServeOpts {
        resize_interval_ms: 5,
        objects: vec![ObjectManifest {
            name: "jobs".into(),
            kind: "queue".into(),
            backend: "lcrq+elastic:aimd".into(),
        }],
        ..ServeOpts::fixed("127.0.0.1:0", clients + 1, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..clients as u64)
        .map(|i| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut c = TicketClient::connect(&addr).unwrap();
                let mut ranges = Vec::new();
                let mut got = Vec::new();
                for k in 0..per_client {
                    ranges.push((c.take(1 + k % 3, k % 9 == 0).unwrap(), 1 + k % 3));
                    c.enqueue("jobs", (i << 32) | k).unwrap();
                    if let Some(item) = c.dequeue("jobs").unwrap() {
                        got.push(item);
                    }
                }
                (ranges, got)
            })
        })
        .collect();
    let mut ranges = Vec::new();
    let mut consumed = Vec::new();
    for h in handles {
        let (r, g) = h.join().unwrap();
        ranges.extend(r);
        consumed.extend(g);
    }
    // Counter: dense disjoint ranges despite queue traffic.
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap in counter ranges");
        expect = s + c;
    }
    // Queue: drain the stragglers, then the multiset must be exact.
    let mut c = TicketClient::connect(&addr).unwrap();
    while let Some(item) = c.dequeue("jobs").unwrap() {
        consumed.push(item);
    }
    consumed.sort_unstable();
    let mut expected: Vec<u64> = (0..clients as u64)
        .flat_map(|i| (0..per_client).map(move |k| (i << 32) | k))
        .collect();
    expected.sort_unstable();
    assert_eq!(consumed, expected, "queue lost or duplicated items");

    // Independent per-object stats.
    let tickets = c.stats().unwrap();
    let jobs = c.stats_on("jobs").unwrap();
    assert_eq!(tickets.get("kind").and_then(Json::as_str), Some("counter"));
    assert_eq!(jobs.get("kind").and_then(Json::as_str), Some("queue"));
    let takes = tickets.get("take").and_then(Json::as_u64).unwrap()
        + tickets.get("take_priority").and_then(Json::as_u64).unwrap();
    assert_eq!(takes, clients as u64 * per_client);
    assert!(tickets.get("enqueue").is_none(), "no queue traffic on the counter");
    assert!(jobs.get("enqueue").and_then(Json::as_u64).unwrap() >= clients as u64 * per_client);
    assert!(jobs.get("take").is_none(), "no counter traffic on the queue");
    // Both objects expose their own (elastic) width and contention
    // counters, sized by their own capacity.
    let t_width = tickets.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=2).contains(&t_width), "counter width {t_width}");
    let j_width = jobs.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=12).contains(&j_width), "queue index width {j_width}");
    assert!(jobs.get("index_cells").and_then(Json::as_u64).unwrap() >= 2);
    assert!(jobs.get("main_faas").and_then(Json::as_u64).unwrap() > 0);
    let t_ops = tickets.get("batched_ops").and_then(Json::as_u64).unwrap();
    let j_ops = jobs.get("batched_ops").and_then(Json::as_u64).unwrap();
    assert!(t_ops > 0 && j_ops > 0, "both funnels saw traffic");
    server.shutdown();
}

#[test]
fn shutdown_is_prompt_under_concurrent_connects() {
    // The old nudge-based shutdown could hang if its wake-up
    // connection was consumed as a client; the polling accept loop
    // must shut down promptly even while new clients keep arriving.
    for _ in 0..5 {
        let server = start(2);
        let addr = server.addr.to_string();
        let spam = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // Connections racing the stop flag; errors are fine.
                for _ in 0..20 {
                    let _ = std::net::TcpStream::connect(&addr);
                }
            })
        };
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "shutdown took {:?}",
            t0.elapsed()
        );
        spam.join().unwrap();
    }
}

#[test]
fn malformed_requests_do_not_kill_connection() {
    use std::io::{BufRead, Write};
    let server = start(2);
    let conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(conn);
    for bad in ["not json", "{}", "{\"op\":42}", "{\"op\":\"bogus\"}"] {
        writer.write_all(bad.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
    }
    // Still serviceable afterwards.
    writer.write_all(b"{\"op\":\"take\",\"count\":2}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();
}
