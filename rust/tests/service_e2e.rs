//! End-to-end tests of the ticket service over real TCP: concurrent
//! clients, priority requests, error handling, and shutdown.

use std::sync::Arc;

use aggfunnels::service::{serve, ServeOpts, TicketClient};
use aggfunnels::util::json::Json;

fn start(workers: usize) -> aggfunnels::service::ServerHandle {
    serve(&ServeOpts::fixed("127.0.0.1:0", workers, 2)).unwrap()
}

#[test]
fn many_clients_disjoint_coverage() {
    let server = start(4);
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut c = TicketClient::connect(&addr).unwrap();
                let mut out = Vec::new();
                for k in 0..200u64 {
                    let count = 1 + (i as u64 + k) % 5;
                    let start = c.take(count, k % 10 == 0).unwrap();
                    out.push((start, count));
                }
                out
            })
        })
        .collect();
    let mut ranges: Vec<(u64, u64)> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap in dispensed tickets");
        expect = s + c;
    }
    let mut c = TicketClient::connect(&addr).unwrap();
    assert_eq!(c.read().unwrap(), expect);
    server.shutdown();
}

#[test]
fn stats_reflect_traffic() {
    let server = start(2);
    let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
    for _ in 0..5 {
        c.take(1, false).unwrap();
    }
    c.take(1, true).unwrap();
    c.read().unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.get("take").and_then(Json::as_u64).unwrap() >= 5);
    assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
    assert!(stats.get("read").and_then(Json::as_u64).unwrap() >= 1);
    server.shutdown();
}

#[test]
fn adaptive_service_survives_burst_and_reports_width() {
    // An AIMD-managed server under a client burst: tickets must stay
    // disjoint and dense, and stats must expose the live width.
    let server = serve(&ServeOpts {
        policy: aggfunnels::faa::WidthPolicy::Aimd(Default::default()),
        max_aggregators: 8,
        resize_interval_ms: 5,
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut c = TicketClient::connect(&addr).unwrap();
                let mut out = Vec::new();
                for _ in 0..300u64 {
                    out.push((c.take(1, false).unwrap(), 1u64));
                }
                out
            })
        })
        .collect();
    let mut ranges: Vec<(u64, u64)> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    ranges.sort_unstable();
    let mut expect = 0;
    for (s, c) in ranges {
        assert_eq!(s, expect, "gap or overlap while resizing");
        expect = s + c;
    }
    let mut c = TicketClient::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    let width = stats.get("active_width").and_then(Json::as_u64).unwrap();
    assert!((1..=8).contains(&width), "width {width} out of range");
    assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("aimd"));
    server.shutdown();
}

#[test]
fn malformed_requests_do_not_kill_connection() {
    use std::io::{BufRead, Write};
    let server = start(2);
    let conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(conn);
    for bad in ["not json", "{}", "{\"op\":42}", "{\"op\":\"bogus\"}"] {
        writer.write_all(bad.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
    }
    // Still serviceable afterwards.
    writer.write_all(b"{\"op\":\"take\",\"count\":2}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    server.shutdown();
}
