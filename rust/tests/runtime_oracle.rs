//! PJRT runtime integration: the AOT JAX/Pallas oracle must agree with
//! the CPU reference on random histories, pad correctly at every
//! compiled size, and back the full verify pipeline.
//!
//! Requires `make artifacts`; every test degrades to a skip (with a
//! loud message) when the artifacts are missing so `cargo test` works
//! in a fresh checkout.

use aggfunnels::runtime::{batch_returns_cpu, BatchHistory, OracleRuntime};
use aggfunnels::util::rng::Rng;
use aggfunnels::verify::{verify_faa_run, OracleBackend};

fn runtime_or_skip() -> Option<OracleRuntime> {
    match OracleRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn random_history(rng: &mut Rng, batches: usize, max_batch: usize) -> BatchHistory {
    let mut h = BatchHistory::default();
    let mut main: u64 = rng.next_u64();
    for _ in 0..batches {
        let len = rng.range_inclusive(1, max_batch as u64) as usize;
        let deltas: Vec<u64> = (0..len).map(|_| rng.range_inclusive(1, 100)).collect();
        let sign = if rng.chance(0.5) { 1 } else { -1 };
        h.push_batch(main, sign, &deltas);
        let sum: u64 = deltas.iter().sum();
        main = if sign > 0 { main.wrapping_add(sum) } else { main.wrapping_sub(sum) };
    }
    h
}

#[test]
fn oracle_matches_cpu_on_random_histories() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0x02AC_1E);
    for case in 0..20 {
        let h = random_history(&mut rng, 1 + case % 40, 12);
        let got = rt.batch_returns(&h).unwrap();
        let want = batch_returns_cpu(&h);
        assert_eq!(got, want, "case {case}");
    }
}

#[test]
fn oracle_handles_every_compiled_size() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(7);
    // Sizes straddling each compiled artifact boundary.
    for target_ops in [1usize, 1000, 1024, 1025, 4000, 4100, 16000] {
        let mut h = BatchHistory::default();
        let mut remaining = target_ops;
        let mut main = 0u64;
        while remaining > 0 {
            let len = remaining.min(rng.range_inclusive(1, 9) as usize);
            let deltas: Vec<u64> = (0..len).map(|_| rng.range_inclusive(1, 100)).collect();
            h.push_batch(main, 1, &deltas);
            main = main.wrapping_add(deltas.iter().sum::<u64>());
            remaining -= len;
        }
        let got = rt.batch_returns(&h).unwrap();
        assert_eq!(got, batch_returns_cpu(&h), "{target_ops} ops");
        assert_eq!(got.len(), target_ops);
    }
}

#[test]
fn oracle_rejects_oversized_history() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut h = BatchHistory::default();
    for i in 0..17_000u64 {
        h.push_batch(i, 1, &[1]);
    }
    assert!(rt.batch_returns(&h).is_err());
}

#[test]
fn oracle_chunked_handles_large_histories() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(99);
    let h = random_history(&mut rng, 9_000, 8); // ~40k ops on average
    let got = rt.batch_returns_chunked(&h).unwrap();
    assert_eq!(got, batch_returns_cpu(&h));
}

#[test]
fn oracle_wraps_mod_2_64() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut h = BatchHistory::default();
    h.push_batch(u64::MAX - 1, 1, &[3, 4]);
    h.push_batch(2, -1, &[5, 7]);
    let got = rt.batch_returns(&h).unwrap();
    // batch0: base 2⁶⁴−2, +3 wraps to 1; batch1: base 2, −5 wraps to 2⁶⁴−3.
    assert_eq!(got, vec![u64::MAX - 1, 1, 2, u64::MAX - 2]);
    assert_eq!(got, batch_returns_cpu(&h));
}

#[test]
fn full_verify_pipeline_via_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let backend = OracleBackend::Pjrt(rt);
    let report = verify_faa_run(6, 3, 2_000, 0xABCD, &backend).unwrap();
    assert_eq!(report.ops, 12_000);
    assert_eq!(report.checked_against, "pjrt-aot-oracle");
}
