//! Randomized property tests (hand-rolled proptest-style helper) over
//! the paper's invariants and the crate's substrates.

use std::sync::Arc;

use aggfunnels::faa::{AggFunnel, AggFunnelConfig, FetchAddObject};
use aggfunnels::runtime::{batch_returns_cpu, BatchHistory};
use aggfunnels::sim::algos::AlgoSpec;
use aggfunnels::sim::workloads::{run_faa_point, FaaWorkload};
use aggfunnels::sim::SimConfig;
use aggfunnels::util::json::Json;
use aggfunnels::util::prop::{check, run as prop_run, PropConfig};
use aggfunnels::util::tomlmini::{TomlDoc, TomlValue};
use aggfunnels::verify::{verify_faa_run, OracleBackend};
use aggfunnels::{prop_assert, prop_assert_eq};

/// Lemma 3.4 + Invariants 3.1/3.3 over random concurrent runs with
/// random thread counts, Aggregator counts and seeds.
#[test]
fn prop_faa_runs_linearizable() {
    prop_run(
        "faa_runs_linearizable",
        PropConfig { cases: 12, seed: 0xFA4, max_size: 6 },
        |c| {
            let threads = 1 + c.rng.below(6) as usize;
            let m = 1 + c.rng.below(4) as usize;
            let ops = 200 + c.rng.below(800) as usize;
            let seed = c.rng.next_u64();
            verify_faa_run(threads, m, ops, seed, &OracleBackend::Cpu)
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

/// The overflow/retire path preserves dense fetch-and-inc tickets for
/// random tiny thresholds.
#[test]
fn prop_overflow_path_dense() {
    prop_run(
        "overflow_dense",
        PropConfig { cases: 10, seed: 0x0F, max_size: 8 },
        |c| {
            let p = 2 + c.rng.below(4) as usize;
            let threshold = 16 + c.rng.below(512);
            let per_thread = 800u64;
            let f = Arc::new(AggFunnel::with_config(
                AggFunnelConfig::new(p).with_aggregators(1 + c.rng.below(3) as usize).with_threshold(threshold),
            ));
            let handles: Vec<_> = (0..p)
                .map(|tid| {
                    let f = Arc::clone(&f);
                    std::thread::spawn(move || {
                        (0..per_thread).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                    })
                })
                .collect();
            let mut all: Vec<u64> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            all.sort_unstable();
            let n = p as u64 * per_thread;
            prop_assert_eq!(all.len() as u64, n);
            prop_assert!(
                all == (0..n).collect::<Vec<_>>(),
                "tickets not dense with threshold {threshold}"
            );
            Ok(())
        },
    );
}

/// The CPU oracle itself: results within a batch are strictly
/// base + prefix, independent of how the history is split into
/// batches (merging adjacent same-sign batches with adjusted bases
/// yields the same returns).
#[test]
fn prop_oracle_batch_split_invariance() {
    check("oracle_split_invariance", |c| {
        // Build a random positive-only run, then express it as (a) one
        // batch and (b) random sub-batches with correct bases.
        let deltas = c.nonempty_vec_of(|r| r.range_inclusive(1, 100));
        let base = c.rng.next_u64();
        let mut single = BatchHistory::default();
        single.push_batch(base, 1, &deltas);
        let want = batch_returns_cpu(&single);

        let mut split = BatchHistory::default();
        let mut i = 0;
        let mut cur_base = base;
        while i < deltas.len() {
            let len = 1 + c.rng.below((deltas.len() - i) as u64) as usize;
            let chunk = &deltas[i..i + len];
            split.push_batch(cur_base, 1, chunk);
            cur_base = cur_base.wrapping_add(chunk.iter().sum::<u64>());
            i += len;
        }
        let got = batch_returns_cpu(&split);
        prop_assert_eq!(got, want);
        Ok(())
    });
}

/// Simulator determinism across random seeds and thread counts.
#[test]
fn prop_sim_deterministic() {
    prop_run(
        "sim_deterministic",
        PropConfig { cases: 6, seed: 0xD5, max_size: 4 },
        |c| {
            let threads = 2 + c.rng.below(24) as usize;
            let seed = c.rng.next_u64();
            let mut cfg = SimConfig::c3_standard_176(threads);
            cfg.horizon_cycles = 150_000;
            cfg.seed = seed;
            let wl = FaaWorkload::update_heavy();
            let spec = AlgoSpec::Agg { m: 1 + c.rng.below(4) as usize, direct: 0 };
            let a = run_faa_point(&cfg, &spec, &wl);
            let b = run_faa_point(&cfg, &spec, &wl);
            prop_assert_eq!(a.sim_events, b.sim_events);
            prop_assert!(a.mops == b.mops, "throughput differed across identical runs");
            Ok(())
        },
    );
}

/// JSON round-trip for random values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(r: &mut aggfunnels::util::rng::Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.chance(0.5)),
            2 => Json::Num((r.next_u64() % 1_000_000) as f64),
            3 => Json::Str(format!("s{}-\"esc\"\n", r.next_u64() % 1000)),
            4 => Json::Arr((0..r.below(4)).map(|_| random_json(r, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..r.below(4) {
                    m.insert(format!("k{i}"), random_json(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check("json_roundtrip", |c| {
        let v = random_json(c.rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).map_err(|e| format!("parse failed on {s}: {e}"))?;
        prop_assert_eq!(back, v);
        Ok(())
    });
}

/// TOML parser: values render→parse round-trip.
#[test]
fn prop_toml_value_roundtrip() {
    check("toml_roundtrip", |c| {
        let n = c.rng.next_u64() as i64 / 2;
        let f = (c.rng.next_u64() % 10_000) as f64 / 7.0;
        let b = c.rng.chance(0.5);
        let arr: Vec<i64> = c.vec_of(|r| r.next_u64() as i64 / 2);
        let text = format!(
            "i = {n}\nf = {f}\nb = {b}\narr = [{}]\n[t]\ns = \"hello world\"",
            arr.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
        );
        let doc = TomlDoc::parse(&text).map_err(|e| e)?;
        prop_assert_eq!(doc.int_or("i", -1), n);
        prop_assert!((doc.float_or("f", -1.0) - f).abs() < 1e-9, "float mismatch");
        prop_assert_eq!(doc.bool_or("b", !b), b);
        prop_assert_eq!(doc.str_or("t.s", ""), "hello world".to_string());
        let got: Vec<i64> = doc
            .get("arr")
            .and_then(TomlValue::as_array)
            .map(|a| a.iter().filter_map(TomlValue::as_int).collect())
            .unwrap_or_default();
        prop_assert_eq!(got, arr);
        Ok(())
    });
}

/// Random mixed-sign sums conserve across every batch configuration.
#[test]
fn prop_mixed_sign_sum_conservation() {
    prop_run(
        "mixed_sign_sum",
        PropConfig { cases: 8, seed: 0x51, max_size: 6 },
        |c| {
            let p = 1 + c.rng.below(5) as usize;
            let m = 1 + c.rng.below(6) as usize;
            let f = Arc::new(AggFunnel::with_config(AggFunnelConfig::new(p).with_aggregators(m)));
            let per_thread = 500;
            let seeds: Vec<u64> = (0..p).map(|_| c.rng.next_u64()).collect();
            let handles: Vec<_> = (0..p)
                .map(|tid| {
                    let f = Arc::clone(&f);
                    let seed = seeds[tid];
                    std::thread::spawn(move || {
                        let mut rng = aggfunnels::util::rng::Rng::new(seed);
                        let mut sum = 0i64;
                        for _ in 0..per_thread {
                            let mag = rng.range_inclusive(1, 100) as i64;
                            let d = if rng.chance(0.5) { mag } else { -mag };
                            f.fetch_add(tid, d);
                            sum += d;
                        }
                        sum
                    })
                })
                .collect();
            let expected: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            prop_assert_eq!(f.read(0) as i64, expected);
            Ok(())
        },
    );
}
