//! Adversarial end-to-end tests: hostile traffic shapes driven
//! through the real TCP service and the native funnels, checked
//! against exact oracles rather than throughput expectations —
//! Zipfian key skew, connection churn, reader floods, and recorded
//! runs validated against the linearization oracle under every
//! shipped CAS retry policy.

use std::sync::Arc;

use aggfunnels::bench::adversarial::Zipf;
use aggfunnels::config::ObjectManifest;
use aggfunnels::faa::{AggFunnel, AggFunnelConfig, FetchAddObject};
use aggfunnels::service::{serve, RegistryClient, ServeOpts, DEFAULT_OBJECT};
use aggfunnels::sync::RetryPolicy;
use aggfunnels::util::rng::Rng;
use aggfunnels::verify::{encode_item, verify_history_against, FifoChecker, OracleBackend};

const BANK: usize = 8;

#[test]
fn zipfian_skew_is_exact_under_every_policy() {
    // Zipf-skewed single-ticket takes over a bank of counters, each
    // counter carrying an explicit `:b<policy>` suffix. The oracle is
    // dense-range exactness per key: every counter must end at
    // precisely the number of takes aimed at it — under the hottest
    // key taking roughly half the traffic, for all four policies.
    const THREADS: usize = 4;
    const OPS: usize = 250;
    for policy in RetryPolicy::ALL {
        let label = policy.label();
        let objects: Vec<ObjectManifest> = (0..BANK)
            .map(|k| {
                ObjectManifest::new(
                    format!("c{k}"),
                    "counter",
                    format!("elastic:fixed:2:b{label}"),
                )
            })
            .collect();
        let server =
            serve(&ServeOpts { objects, ..ServeOpts::fixed("127.0.0.1:0", THREADS + 1, 2) })
                .unwrap();
        let addr = Arc::new(server.addr.to_string());

        let workers: Vec<_> = (0..THREADS)
            .map(|tid| {
                let addr = Arc::clone(&addr);
                std::thread::spawn(move || {
                    let c = RegistryClient::connect(&addr).unwrap();
                    let bank: Vec<_> =
                        (0..BANK).map(|k| c.counter(&format!("c{k}")).unwrap()).collect();
                    let zipf = Zipf::new(BANK, 1.2);
                    let mut rng = Rng::new(0x5EED ^ (tid as u64).wrapping_mul(0x9E37_79B9));
                    let mut tally = [0u64; BANK];
                    for _ in 0..OPS {
                        let k = zipf.sample(&mut rng);
                        bank[k].take(1).unwrap();
                        tally[k] += 1;
                    }
                    tally
                })
            })
            .collect();
        let mut expect = [0u64; BANK];
        for w in workers {
            for (k, n) in w.join().unwrap().into_iter().enumerate() {
                expect[k] += n;
            }
        }

        let observer = RegistryClient::connect(&addr).unwrap();
        let mut total = 0u64;
        for (k, &want) in expect.iter().enumerate() {
            let got = observer.counter(&format!("c{k}")).unwrap().read().unwrap();
            assert_eq!(got, want, "policy {label}: counter c{k} lost or duplicated takes");
            total += got;
        }
        assert_eq!(total, (THREADS * OPS) as u64, "policy {label}: total take count drifted");
        // The skew actually concentrated: the hottest key dominates.
        assert!(
            expect[0] > expect[BANK - 1] * 2,
            "policy {label}: workload was not skewed ({expect:?})"
        );
        server.shutdown();
    }
}

#[test]
fn churn_and_reader_flood_preserve_exact_multisets() {
    // Connection churn (every burst on a fresh socket) plus a
    // reader-heavy flood, mixing counter takes/reads with queue
    // traffic. The oracles are exact: the counter's dense range over
    // all takes, and the queue's item multiset with per-producer FIFO
    // order across everything consumed.
    const THREADS: usize = 4;
    const BURSTS: usize = 25;
    const ENQ_PER_BURST: u64 = 2;
    let server = serve(&ServeOpts {
        objects: vec![ObjectManifest::new("jobs", "queue", "lcrq+elastic")],
        ..ServeOpts::fixed("127.0.0.1:0", THREADS + 1, 2)
    })
    .unwrap();
    let addr = Arc::new(server.addr.to_string());

    let workers: Vec<_> = (0..THREADS)
        .map(|tid| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0FF_EE ^ (tid as u64).wrapping_mul(6271));
                let mut takes = 0u64;
                let mut seq = 0u64;
                let mut consumed = Vec::new();
                for _ in 0..BURSTS {
                    // Churn: a fresh connection per burst.
                    let c = RegistryClient::connect(&addr).unwrap();
                    let tickets = c.counter(DEFAULT_OBJECT).unwrap();
                    let jobs = c.queue("jobs").unwrap();
                    for _ in 0..ENQ_PER_BURST {
                        jobs.enqueue(encode_item(tid, seq)).unwrap();
                        seq += 1;
                    }
                    // Reader flood: most counter ops are reads.
                    for _ in 0..8 {
                        if rng.chance(0.75) {
                            tickets.read().unwrap();
                        } else {
                            tickets.take(1).unwrap();
                            takes += 1;
                        }
                    }
                    if let Some(item) = jobs.dequeue().unwrap() {
                        consumed.push(item);
                    }
                }
                (takes, consumed)
            })
        })
        .collect();

    let mut checker = FifoChecker::new();
    let mut total_takes = 0u64;
    for w in workers {
        let (takes, consumed) = w.join().unwrap();
        total_takes += takes;
        checker.add_stream(consumed);
    }

    // Drain whatever the churny consumers left behind, then demand
    // the exact multiset: every enqueued item exactly once, FIFO per
    // producer within each consumer stream.
    let observer = RegistryClient::connect(&addr).unwrap();
    let jobs = observer.queue("jobs").unwrap();
    let mut leftovers = Vec::new();
    while let Some(item) = jobs.dequeue().unwrap() {
        leftovers.push(item);
    }
    checker.add_stream(leftovers);
    checker.check(THREADS, BURSTS as u64 * ENQ_PER_BURST).unwrap();

    assert_eq!(
        observer.counter(DEFAULT_OBJECT).unwrap().read().unwrap(),
        total_takes,
        "reader flood must not perturb the take count"
    );
    server.shutdown();
}

#[test]
fn live_policy_swaps_mid_storm_stay_exact() {
    // Swapping the CAS retry policy over the wire *while* clients
    // hammer the object must never lose, duplicate, or reorder a
    // grant — the swap is a pacing change, not a correctness event.
    const THREADS: usize = 4;
    const OPS: usize = 200;
    let server = serve(&ServeOpts::fixed("127.0.0.1:0", THREADS + 2, 2)).unwrap();
    let addr = Arc::new(server.addr.to_string());

    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let tickets =
                    RegistryClient::connect(&addr).unwrap().counter(DEFAULT_OBJECT).unwrap();
                let mut got = Vec::with_capacity(OPS);
                for _ in 0..OPS {
                    got.push(tickets.take(1).unwrap());
                }
                got
            })
        })
        .collect();

    // Sweep through every policy mid-storm.
    let admin = RegistryClient::connect(&addr).unwrap();
    let tickets = admin.counter(DEFAULT_OBJECT).unwrap();
    for policy in RetryPolicy::ALL.iter().cycle().take(12) {
        assert_eq!(tickets.set_policy(policy.label()).unwrap(), policy.label());
        std::thread::sleep(std::time::Duration::from_millis(2));
    }

    let mut grants: Vec<u64> = workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    grants.sort_unstable();
    let expect: Vec<u64> = (0..(THREADS * OPS) as u64).collect();
    assert_eq!(grants, expect, "grants must stay dense across live policy swaps");
    server.shutdown();
}

#[test]
fn oracle_validates_recorded_runs_under_every_policy() {
    // The deepest check: a recording funnel under each CAS retry
    // policy, every recorded return value replayed against the
    // linearization oracle (Lemma 3.4), plus sum conservation
    // (Invariant 3.3). Pacing decisions must be invisible to the
    // linearized history.
    const THREADS: usize = 4;
    const OPS: usize = 1_500;
    for policy in RetryPolicy::ALL {
        let cfg = AggFunnelConfig::new(THREADS).with_aggregators(3).with_recording();
        let funnel = Arc::new(AggFunnel::with_config(cfg));
        funnel.set_cas_policy(policy);
        assert_eq!(funnel.cas_policy(), Some(policy));

        let handles: Vec<_> = (0..THREADS)
            .map(|tid| {
                let f = Arc::clone(&funnel);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(0xFEED ^ (tid as u64).wrapping_mul(0x9E37_79B9));
                    let mut sum = 0i64;
                    for _ in 0..OPS {
                        let mag = rng.range_inclusive(1, 100) as i64;
                        let delta = if rng.chance(0.5) { mag } else { -mag };
                        f.fetch_add(tid, delta);
                        sum += delta;
                    }
                    sum
                })
            })
            .collect();
        let expected_total: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();

        assert_eq!(
            funnel.read(0),
            expected_total as u64,
            "policy {}: sum conservation violated",
            policy.label()
        );
        let (history, recorded) = funnel.extract_history();
        assert_eq!(history.ops(), THREADS * OPS, "policy {}: ops lost", policy.label());
        verify_history_against(&history, &recorded, &OracleBackend::Cpu)
            .unwrap_or_else(|e| panic!("policy {}: oracle mismatch: {e:#}", policy.label()));
    }
}
