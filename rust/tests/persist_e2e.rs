//! End-to-end durability tests: crash a server (drop without the
//! final flush/snapshot), restart it on the same `data_dir`, and
//! prove the registry comes back — same object set, monotonic
//! counters with no duplicate ticket grants, exact queue multisets.

use std::collections::BTreeMap;
use std::sync::Arc;

use aggfunnels::config::ObjectManifest;
use aggfunnels::service::{
    serve, CreateSpec, PersistOpts, RegistryClient, ServeOpts, DEFAULT_OBJECT,
};
use aggfunnels::util::json::Json;

/// Unique scratch `data_dir` for one test.
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    aggfunnels::util::scratch_dir(&format!("e2e-{tag}"))
}

fn dir_str(dir: &std::path::Path) -> String {
    dir.to_string_lossy().into_owned()
}

#[test]
fn crash_recovery_restores_counters_and_queues_exactly() {
    let dir = scratch_dir("crash-exact");
    let serve_opts = |dir: &std::path::Path| ServeOpts {
        // Synchronous mode: every acked response's record is durable,
        // so a crash loses nothing that was acknowledged.
        persist: Some(PersistOpts::sync(dir_str(dir))),
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    };
    let server = serve(&serve_opts(&dir)).unwrap();
    let addr = server.addr.to_string();

    // Build a namespace and a ledger of acked operations.
    let mut acked_end = 0u64;
    let mut dequeued = 0usize;
    {
        let c = RegistryClient::connect(&addr).unwrap();
        let jobs = c.create_queue("jobs", &CreateSpec::backend("lcrq+elastic:fixed:2")).unwrap();
        let orders = c.create_counter("orders", &CreateSpec::backend("elastic:aimd:d1")).unwrap();
        for k in 0..200u64 {
            let count = 1 + k % 4;
            let start = if k % 9 == 0 {
                orders.take_priority(count).unwrap()
            } else {
                orders.take(count).unwrap()
            };
            acked_end = acked_end.max(start + count);
            jobs.enqueue(1000 + k).unwrap();
            if k % 3 == 0 {
                // The queue is never empty here (this iteration's
                // enqueue precedes it), so FIFO hands out the oldest
                // surviving item.
                assert_eq!(jobs.dequeue().unwrap(), Some(1000 + dequeued as u64));
                dequeued += 1;
            }
        }
    }
    // Acked enqueues minus acked dequeues: the oldest `dequeued`
    // items are gone, the rest survive in FIFO order.
    let expected: Vec<u64> = (0..200u64).map(|k| 1000 + k).skip(dequeued).collect();

    // Crash: no graceful flush, no final snapshot.
    server.crash();

    // Restart on the same data_dir.
    let server = serve(&serve_opts(&dir)).unwrap();
    let addr = server.addr.to_string();
    let c = RegistryClient::connect(&addr).unwrap();

    // Same object set, same backends.
    let listed = c.list().unwrap();
    let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
    assert_eq!(names, vec!["jobs", "orders", "tickets"]);
    let orders_row = listed.iter().find(|(n, _, _)| n == "orders").unwrap();
    assert_eq!(orders_row.2, "elastic:aimd:d1", "backend (and its direct quota) survives");

    // Counter: resumes exactly at the last acked value; fresh takes
    // never re-issue an acked ticket.
    let orders = c.counter("orders").unwrap();
    assert_eq!(orders.read().unwrap(), acked_end, "counter must resume at last ack");
    let fresh = orders.take(1).unwrap();
    assert_eq!(fresh, acked_end, "no gap, no duplicate grant");

    // Queue: exact multiset of acked enqueues minus acked dequeues,
    // in FIFO order.
    let jobs = c.queue("jobs").unwrap();
    let mut drained = Vec::new();
    while let Some(item) = jobs.dequeue().unwrap() {
        drained.push(item);
    }
    assert_eq!(drained, expected, "queue multiset (and order) must survive the crash");

    // Recovery-aware stats: the shard reports what it replayed.
    let agg = c.cluster_stats().unwrap();
    let per_shard = agg.get("per_shard").and_then(Json::as_arr).unwrap();
    assert_eq!(per_shard[0].get("persist").and_then(Json::as_bool), Some(true));
    let totals = agg.get("totals").unwrap();
    assert!(totals.get("take").is_some());
    let replayed: u64 = per_shard
        .iter()
        .filter_map(|s| s.get("wal_replayed").and_then(Json::as_u64))
        .sum();
    let recovered: u64 = per_shard
        .iter()
        .filter_map(|s| s.get("recovered_objects").and_then(Json::as_u64))
        .sum();
    assert!(replayed > 0, "the WAL tail must have been replayed");
    assert_eq!(recovered, 3, "all three objects recovered");
    // Per-object stats advertise durability.
    let stats = orders.stats().unwrap();
    assert_eq!(stats.get("persist").and_then(Json::as_bool), Some(true));

    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn crash_recovery_restores_stacks_in_lifo_order() {
    let dir = scratch_dir("crash-stack");
    let serve_opts = |dir: &std::path::Path| ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(dir))),
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    };
    let server = serve(&serve_opts(&dir)).unwrap();
    let addr = server.addr.to_string();

    // Push a mixed-type history and pop part of it back, tracking the
    // model stack the survivor must equal.
    let mut model: Vec<u64> = Vec::new();
    {
        let c = RegistryClient::connect(&addr).unwrap();
        let undo = c.create_stack("undo", &CreateSpec::backend("stack+elastic:fixed:2")).unwrap();
        undo.push_bytes(b"marker").unwrap();
        for k in 0..150u64 {
            undo.push(7000 + k).unwrap();
            model.push(7000 + k);
            if k % 5 == 4 {
                // Two-phase locally: this pop races nothing, so it
                // must return the model's top.
                assert_eq!(undo.pop().unwrap(), model.pop());
            }
        }

        // The lock-free journal's own counters surface in the cluster
        // aggregate: every durable mutation was one claim-stack push,
        // and the flusher claimed them in batches.
        let agg = c.cluster_stats().unwrap();
        let per_shard = agg.get("per_shard").and_then(Json::as_arr).unwrap();
        let sum = |key: &str| -> u64 {
            per_shard.iter().filter_map(|s| s.get(key).and_then(Json::as_u64)).sum()
        };
        assert!(sum("journal_pushes") > 150, "every push/pop journaled");
        assert!(sum("journal_drains") >= 1, "the flusher must have claimed batches");
        assert!(
            sum("journal_pushes") >= sum("journal_drains"),
            "a drain claims at least one record"
        );
        let batch_max =
            per_shard.iter().filter_map(|s| s.get("journal_batch_max").and_then(Json::as_u64)).max();
        assert!(batch_max.unwrap_or(0) >= 1, "per-shard journal_batch_max reported");
        assert!(
            per_shard
                .iter()
                .any(|s| s.get("journal_batch_avg").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0),
            "per-shard journal_batch_avg reported"
        );
    }
    server.crash();

    let server = serve(&serve_opts(&dir)).unwrap();
    let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
    let listed = c.list().unwrap();
    let undo_row = listed.iter().find(|(n, _, _)| n == "undo").unwrap();
    assert_eq!(undo_row.1, "stack");
    assert_eq!(undo_row.2, "stack+elastic:fixed:2", "stack backend survives");

    // The survivor pops in exact LIFO order down to the byte marker.
    let undo = c.stack("undo").unwrap();
    while let Some(expected) = model.pop() {
        assert_eq!(undo.pop().unwrap(), Some(expected), "LIFO order after recovery");
    }
    assert_eq!(
        undo.pop_item().unwrap(),
        Some(aggfunnels::service::frame::Item::Bytes(b"marker".to_vec())),
        "bottom byte-string item survives"
    );
    assert_eq!(undo.pop_item().unwrap(), None, "stack drained");
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn crash_mid_workload_never_duplicates_grants() {
    let dir = scratch_dir("crash-mid");
    let serve_opts = |dir: &std::path::Path| ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(dir))),
        ..ServeOpts::fixed("127.0.0.1:0", 5, 2)
    };
    let server = serve(&serve_opts(&dir)).unwrap();
    let addr = Arc::new(server.addr.to_string());

    // Hammer the default counter until the server dies under us.
    let workers: Vec<_> = (0..3)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                let mut acked: Vec<(u64, u64)> = Vec::new();
                let Ok(c) = RegistryClient::connect(&addr) else { return acked };
                let Ok(tickets) = c.counter(DEFAULT_OBJECT) else { return acked };
                loop {
                    match tickets.take(2) {
                        Ok(start) => acked.push((start, 2)),
                        Err(_) => return acked, // server crashed mid-flight
                    }
                }
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(150));
    server.crash();
    let mut acked: Vec<(u64, u64)> = workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    assert!(!acked.is_empty(), "the workload must have made progress before the crash");

    // Acked ranges are mutually disjoint…
    acked.sort_unstable();
    for pair in acked.windows(2) {
        assert!(pair[0].0 + pair[0].1 <= pair[1].0, "overlapping acked ranges {pair:?}");
    }
    let max_acked_end = acked.last().map(|(s, c)| s + c).unwrap();

    // …and the recovered counter sits at or above every acked range,
    // so post-restart grants can never duplicate one. (It may sit
    // above the last *acked* end: an in-flight take can be journaled
    // before its response is lost to the crash — durability errs
    // toward never re-issuing a value.)
    let server = serve(&serve_opts(&dir)).unwrap();
    let tickets = RegistryClient::connect(&server.addr.to_string())
        .unwrap()
        .counter(DEFAULT_OBJECT)
        .unwrap();
    let recovered = tickets.read().unwrap();
    assert!(
        recovered >= max_acked_end,
        "recovered value {recovered} below acked end {max_acked_end}: duplicate grants possible"
    );
    let fresh = tickets.take(1).unwrap();
    assert!(fresh >= max_acked_end, "fresh grant {fresh} collides with an acked range");
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sharded_server_restarts_with_same_namespace_and_values() {
    // The acceptance path: S = 2, group-commit WAL, graceful
    // shutdown, restart from the same data_dir.
    let dir = scratch_dir("sharded");
    let serve_opts = |dir: &std::path::Path| ServeOpts {
        resize_interval_ms: 5,
        persist: Some(PersistOpts {
            data_dir: dir_str(dir),
            fsync_interval_ms: 2,
            snapshot_interval_ms: 0,
        }),
        ..ServeOpts::sharded("127.0.0.1:0", 2, 5, 2)
    };
    // These names cover both shards at S = 2 (pinned by the
    // service-shard bench tests).
    let counters = ["orders", "users"];
    let queues = ["jobs", "mail"];

    let mut final_counts: BTreeMap<&str, u64> = BTreeMap::new();
    let mut expected_items: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
    let server = serve(&serve_opts(&dir)).unwrap();
    {
        let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.shards(), 2);
        let spread: std::collections::BTreeSet<usize> = counters
            .iter()
            .chain(queues.iter())
            .map(|n| c.shard_for(n))
            .collect();
        assert_eq!(spread.len(), 2, "objects must land on both shards");
        for name in counters {
            c.create_counter(name, &CreateSpec::backend("elastic:fixed:2")).unwrap();
        }
        for name in queues {
            c.create_queue(name, &CreateSpec::backend("lcrq+elastic:fixed:2")).unwrap();
        }
        for k in 0..120u64 {
            let counter = c.counter(counters[(k % 2) as usize]).unwrap();
            let queue = c.queue(queues[(k % 2) as usize]).unwrap();
            let count = 1 + k % 3;
            counter.take(count).unwrap();
            *final_counts.entry(counters[(k % 2) as usize]).or_insert(0) += count;
            queue.enqueue(5000 + k).unwrap();
            expected_items.entry(queues[(k % 2) as usize]).or_default().push(5000 + k);
            if k % 4 == 0 {
                let item = queue.dequeue().unwrap().unwrap();
                let items = expected_items.get_mut(queues[(k % 2) as usize]).unwrap();
                let pos = items.iter().position(|x| *x == item).unwrap();
                items.remove(pos);
            }
        }
    }
    // Graceful shutdown: the final journal window is flushed and each
    // shard writes a snapshot.
    server.shutdown();

    let server = serve(&serve_opts(&dir)).unwrap();
    let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
    assert_eq!(c.shards(), 2, "restart keeps the shard layout");

    // Same object set across both shards.
    let listed = c.list().unwrap();
    let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
    assert_eq!(names, vec!["jobs", "mail", "orders", "tickets", "users"]);

    // Counters: exact values, and still monotonic under new traffic.
    for name in counters {
        let h = c.counter(name).unwrap();
        let value = h.read().unwrap();
        assert_eq!(value, final_counts[name], "{name}: counter value after restart");
        assert_eq!(h.take(1).unwrap(), value, "{name}: no duplicate grants");
    }
    // Queues: exact multisets.
    for name in queues {
        let q = c.queue(name).unwrap();
        let mut drained = Vec::new();
        while let Some(item) = q.dequeue().unwrap() {
            drained.push(item);
        }
        drained.sort_unstable();
        let mut expected = expected_items.remove(name).unwrap();
        expected.sort_unstable();
        assert_eq!(drained, expected, "{name}: queue multiset after restart");
    }
    // Both shards report persistence in the cluster aggregate.
    let agg = c.cluster_stats().unwrap();
    let per_shard = agg.get("per_shard").and_then(Json::as_arr).unwrap();
    assert_eq!(per_shard.len(), 2);
    for shard in per_shard {
        assert_eq!(shard.get("persist").and_then(Json::as_bool), Some(true));
        assert!(shard.get("snapshots").and_then(Json::as_u64).unwrap() >= 1);
    }
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn persist_opt_outs_do_not_survive_restart() {
    let dir = scratch_dir("optout");
    let serve_opts = |dir: &std::path::Path| ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(dir))),
        objects: vec![ObjectManifest {
            persist: false,
            ..ObjectManifest::new("scratchq", "queue", "lcrq+elastic")
        }],
        ..ServeOpts::fixed("127.0.0.1:0", 3, 2)
    };
    let server = serve(&serve_opts(&dir)).unwrap();
    {
        let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
        // Wire-created ephemeral object + traffic into the manifest one.
        let cache =
            c.create_counter("cache", &CreateSpec::backend("elastic:aimd").ephemeral()).unwrap();
        cache.take(50).unwrap();
        c.queue("scratchq").unwrap().enqueue(9).unwrap();
        let stats = cache.stats().unwrap();
        assert_eq!(stats.get("persist").and_then(Json::as_bool), Some(false));
    }
    server.crash();

    let server = serve(&serve_opts(&dir)).unwrap();
    let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
    let listed = c.list().unwrap();
    let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
    // The wire-created ephemeral object is gone; the manifest one is
    // re-created fresh from the manifest (empty again).
    assert_eq!(names, vec!["scratchq", "tickets"]);
    assert_eq!(
        c.queue("scratchq").unwrap().dequeue().unwrap(),
        None,
        "opt-out queue restarts empty"
    );
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn shard_count_change_on_same_data_dir_is_refused() {
    // A shard's log is bound to its slice of the hash space:
    // restarting with a different shard count would strand every
    // object whose name now hashes elsewhere, so the boot must fail
    // loudly instead.
    let dir = scratch_dir("layout");
    let server = serve(&ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(&dir))),
        ..ServeOpts::sharded("127.0.0.1:0", 2, 3, 2)
    })
    .unwrap();
    server.shutdown();
    let err = serve(&ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(&dir))),
        ..ServeOpts::sharded("127.0.0.1:0", 4, 3, 2)
    });
    assert!(err.is_err(), "shard-count change must refuse to boot");
    assert!(
        format!("{:#}", err.err().unwrap()).contains("2-shard"),
        "error must name the recorded layout"
    );
    // The original layout still boots.
    let server = serve(&ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(&dir))),
        ..ServeOpts::sharded("127.0.0.1:0", 2, 3, 2)
    })
    .unwrap();
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn recovered_state_outranks_boot_manifest() {
    let dir = scratch_dir("manifest");
    let serve_opts = |dir: &std::path::Path| ServeOpts {
        persist: Some(PersistOpts::sync(dir_str(dir))),
        objects: vec![ObjectManifest::new("orders", "counter", "elastic:fixed:2")],
        ..ServeOpts::fixed("127.0.0.1:0", 3, 2)
    };
    let server = serve(&serve_opts(&dir)).unwrap();
    {
        let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
        c.counter("orders").unwrap().take(33).unwrap();
        // The default boot counter persists too.
        c.counter(DEFAULT_OBJECT).unwrap().take(4).unwrap();
    }
    server.shutdown();

    let server = serve(&serve_opts(&dir)).unwrap();
    let c = RegistryClient::connect(&server.addr.to_string()).unwrap();
    assert_eq!(
        c.counter("orders").unwrap().read().unwrap(),
        33,
        "manifest must not reset the recovered counter"
    );
    assert_eq!(
        c.counter(DEFAULT_OBJECT).unwrap().read().unwrap(),
        4,
        "default counter value survives restarts"
    );
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}
