//! End-to-end tests for the multiplexed connection layer: many more
//! simultaneous connections than funnel executors, connection churn,
//! pipelined multi-op batches, capacity rejection semantics, and
//! shutdown under load.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

use aggfunnels::service::{
    code_of, serve, ConnOpts, ErrorCode, RegistryClient, ServeOpts, ServerHandle, DEFAULT_OBJECT,
};
use aggfunnels::util::json::Json;

const WORKERS: usize = 4;

fn start_event(workers: usize) -> ServerHandle {
    serve(&ServeOpts::fixed("127.0.0.1:0", workers, 2)).unwrap()
}

/// The single shard's stats entry from a cluster aggregate.
fn shard0(agg: &Json) -> &Json {
    &agg.get("per_shard").and_then(Json::as_arr).unwrap()[0]
}

#[test]
fn event_core_serves_eight_times_the_workers_simultaneously() {
    // The acceptance bar: one shard, `workers` executors, and
    // 8 × workers clients all holding their sockets open at once.
    // Under the legacy thread-per-connection core this would exhaust
    // the tid lease pool; the event core multiplexes them.
    let server = start_event(WORKERS);
    let addr = Arc::new(server.addr.to_string());
    const CONNS: usize = 8 * WORKERS;

    let connected = Arc::new(Barrier::new(CONNS + 1));
    let release = Arc::new(Barrier::new(CONNS + 1));
    let workers: Vec<_> = (0..CONNS)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let connected = Arc::clone(&connected);
            let release = Arc::clone(&release);
            std::thread::spawn(move || {
                let c = RegistryClient::connect(&addr).unwrap();
                let tickets = c.counter(DEFAULT_OBJECT).unwrap();
                connected.wait(); // hold the socket open for the census
                release.wait();
                let start = tickets.take(3).unwrap();
                (start, 3u64)
            })
        })
        .collect();

    // All 32 sockets are open (plus the observer's own): the gauge
    // must show the full census, far past the executor count.
    connected.wait();
    let observer = RegistryClient::connect(&addr).unwrap();
    let agg = observer.cluster_stats().unwrap();
    let shard = shard0(&agg);
    assert_eq!(shard.get("conn_mode").and_then(Json::as_str), Some("event"));
    let open = shard.get("open_conns").and_then(Json::as_u64).unwrap();
    assert!(
        open >= (CONNS + 1) as u64,
        "open_conns {open} must count all {CONNS} held sockets (workers = {WORKERS})"
    );

    // Release the burst: every op lands, grants stay disjoint.
    release.wait();
    let mut ranges: Vec<(u64, u64)> =
        workers.into_iter().map(|w| w.join().unwrap()).collect();
    ranges.sort_unstable();
    for pair in ranges.windows(2) {
        assert!(pair[0].0 + pair[0].1 <= pair[1].0, "overlapping grants {pair:?}");
    }
    let total: u64 = ranges.iter().map(|(_, c)| c).sum();
    assert_eq!(total, (CONNS as u64) * 3);
    assert_eq!(observer.counter(DEFAULT_OBJECT).unwrap().read().unwrap(), total);
    server.shutdown();
}

#[test]
fn pipelined_requests_drain_as_multi_op_batches() {
    // A client that writes a burst of requests before reading any
    // response exercises the batch path end to end: the I/O thread
    // decodes the whole chunk, the executor drains it in one sweep,
    // and the aggregate drain occupancy rises above one op per sweep
    // — the lever the funnels feed on.
    let server = start_event(WORKERS);
    let addr = server.addr.to_string();
    const BURST: usize = 24;

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let burst = "{\"op\":\"take\",\"count\":1}\n".repeat(BURST);
    stream.write_all(burst.as_bytes()).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut starts = Vec::new();
    for _ in 0..BURST {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "bad reply {line}");
        starts.push(resp.get("start").and_then(Json::as_u64).unwrap());
    }
    // The burst is this server's only counter traffic: single-unit
    // takes must cover 0..BURST exactly (in some executor order).
    starts.sort_unstable();
    assert_eq!(starts, (0..BURST as u64).collect::<Vec<_>>());

    let observer = RegistryClient::connect(&addr).unwrap();
    let agg = observer.cluster_stats().unwrap();
    let occupancy = shard0(&agg).get("drain_occupancy").and_then(Json::as_f64).unwrap();
    assert!(
        occupancy > 1.0,
        "drain occupancy {occupancy} must show multi-op batches from the pipelined burst"
    );
    server.shutdown();
}

#[test]
fn connection_churn_lands_every_op() {
    // Hundreds of short-lived sockets against a handful of executors:
    // every connect is admitted, every op acked, and the event core
    // reaps closed sockets instead of leaking slots.
    let server = start_event(WORKERS);
    let addr = Arc::new(server.addr.to_string());
    const THREADS: usize = 6;
    const CONNECTS_PER_THREAD: usize = 50;

    let churners: Vec<_> = (0..THREADS)
        .map(|_| {
            let addr = Arc::clone(&addr);
            std::thread::spawn(move || {
                for _ in 0..CONNECTS_PER_THREAD {
                    // Connect, one op, drop — the whole lifecycle.
                    let c = RegistryClient::connect(&addr).unwrap();
                    c.counter(DEFAULT_OBJECT).unwrap().take(1).unwrap();
                }
            })
        })
        .collect();
    for t in churners {
        t.join().unwrap();
    }

    let total = (THREADS * CONNECTS_PER_THREAD) as u64;
    let observer = RegistryClient::connect(&addr).unwrap();
    assert_eq!(
        observer.counter(DEFAULT_OBJECT).unwrap().read().unwrap(),
        total,
        "every op from every short-lived connection must land"
    );

    // The reaper runs on poll wake-ups, so give the gauge a moment to
    // settle back down to just the observer's own socket.
    let mut open = u64::MAX;
    for _ in 0..200 {
        let agg = observer.cluster_stats().unwrap();
        let shard = shard0(&agg);
        open = shard.get("open_conns").and_then(Json::as_u64).unwrap();
        if open <= 1 {
            // Lifecycle counters: every admitted socket was opened
            // (and all but the observer's closed again).
            let opened = shard.get("conn_open").and_then(Json::as_u64).unwrap();
            let closed = shard.get("conn_closed").and_then(Json::as_u64).unwrap();
            assert!(opened >= total, "conn_open {opened} must count all {total} churned sockets");
            assert_eq!(opened - closed, open, "open/closed counters must reconcile to the gauge");
            server.shutdown();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    panic!("churned connections never reaped: open_conns stuck at {open}");
}

#[test]
fn shutdown_under_load_answers_every_decoded_request() {
    // A client with a pipelined backlog keeps its acked work: graceful
    // shutdown drains the run queue and flushes every response before
    // the socket closes (EOF only after the last reply).
    let server = start_event(2);
    let addr = server.addr.to_string();
    const BACKLOG: usize = 20;

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let burst = "{\"op\":\"take\",\"count\":1}\n".repeat(BACKLOG);
    stream.write_all(burst.as_bytes()).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let read_reply = |reader: &mut BufReader<TcpStream>| -> u64 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "bad reply {line}");
        resp.get("start").and_then(Json::as_u64).unwrap()
    };

    // One reply proves the burst reached the server; then shut down
    // with 19 requests still in flight.
    let mut starts = vec![read_reply(&mut reader)];
    server.shutdown();

    for _ in 1..BACKLOG {
        starts.push(read_reply(&mut reader));
    }
    starts.sort_unstable();
    assert_eq!(starts, (0..BACKLOG as u64).collect::<Vec<_>>(), "every decoded request answered");
    // …and only then EOF.
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no trailing bytes after the last reply");
}

#[test]
fn pipelined_batch_surfaces_errors_in_position() {
    // Regression: a bad op in the middle of a pipelined burst must be
    // answered with an error *in its position* — the requests behind
    // it still execute and their replies never shift or vanish.
    let server = start_event(2);
    let addr = server.addr.to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let burst = concat!(
        "{\"op\":\"take\",\"count\":1}\n",
        "{\"op\":\"no-such-op\"}\n",
        "{\"op\":\"take\",\"count\":1}\n",
        "this is not json\n",
        "{\"op\":\"take\",\"count\":1}\n",
    );
    stream.write_all(burst.as_bytes()).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut replies = Vec::new();
    for _ in 0..5 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        replies.push(Json::parse(line.trim()).unwrap());
    }
    let ok = |r: &Json| r.get("ok").and_then(Json::as_bool) == Some(true);
    let oks: Vec<bool> = replies.iter().map(ok).collect();
    assert_eq!(
        oks,
        [true, false, true, false, true],
        "reply polarity must follow request order: {replies:?}"
    );
    // The valid takes landed in order around the failures; nothing
    // was double-executed or skipped.
    let starts: Vec<u64> =
        [0usize, 2, 4].iter().map(|&i| replies[i].get("start").and_then(Json::as_u64).unwrap()).collect();
    assert_eq!(starts, [0, 1, 2], "grants stay dense around in-batch errors");

    // The connection outlives the bad ops: a follow-up on the same
    // socket still works.
    stream.write_all(b"{\"op\":\"take\",\"count\":1}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("start").and_then(Json::as_u64), Some(3));
    server.shutdown();
}

#[test]
fn overlong_line_is_answered_in_position_and_framing_recovers() {
    // Regression: a newline-terminated line past the 1 MiB cap used to
    // be answered immediately from the I/O thread (jumping the queue)
    // and killed the read side, dropping every request pipelined
    // behind it. It must instead produce a protocol error in its
    // position while the rest of the burst executes normally.
    let server = start_event(2);
    let addr = server.addr.to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    // Matches MAX_LINE in service/conn.rs.
    const CAP: usize = 1 << 20;
    let mut burst = Vec::new();
    burst.extend_from_slice(b"{\"op\":\"take\",\"count\":1}\n");
    burst.extend_from_slice(&vec![b'x'; CAP + 16]);
    burst.push(b'\n');
    burst.extend_from_slice(b"{\"op\":\"take\",\"count\":1}\n");
    stream.write_all(&burst).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut read_json = || {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    let first = read_json();
    assert_eq!(first.get("start").and_then(Json::as_u64), Some(0));
    let err = read_json();
    assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(
        err.get("code").and_then(Json::as_str),
        Some("protocol"),
        "overlong line must be a typed protocol error: {err:?}"
    );
    let third = read_json();
    assert_eq!(
        third.get("start").and_then(Json::as_u64),
        Some(1),
        "the request behind the overlong line must still execute: {third:?}"
    );
    // The newline restored framing, so the connection stays usable.
    stream.write_all(b"{\"op\":\"take\",\"count\":1}\n").unwrap();
    assert_eq!(read_json().get("start").and_then(Json::as_u64), Some(2));
    server.shutdown();
}

#[test]
fn overlong_line_discard_mode_recovers_at_next_newline() {
    // Past the cap with no newline yet: the error reply arrives while
    // the line is still streaming in, the excess is discarded without
    // buffering, and the *next* newline restores framing — the same
    // socket then serves normal requests again.
    let server = start_event(2);
    let addr = server.addr.to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    // Matches MAX_LINE in service/conn.rs.
    const CAP: usize = 1 << 20;
    stream.write_all(&vec![b'y'; CAP + 1]).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut read_json = || {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    let err = read_json();
    assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(err.get("code").and_then(Json::as_str), Some("protocol"));

    // Terminate the monster line; the request behind it executes.
    stream.write_all(b"\n{\"op\":\"take\",\"count\":1}\n").unwrap();
    let resp = read_json();
    assert_eq!(
        resp.get("start").and_then(Json::as_u64),
        Some(0),
        "framing must recover after the discarded line: {resp:?}"
    );
    server.shutdown();
}

#[test]
fn capacity_rejection_is_typed_and_distinct_from_transport_errors() {
    // Regression for the eviction split: a connect past `max_conns`
    // comes back as a clean `AtCapacity` (retryable — the rejected
    // connection never executed anything), while a dead socket is
    // `Io` (never retried — the request may have executed).
    let server = serve(&ServeOpts {
        conn: ConnOpts { max_conns: 1, ..ConnOpts::default() },
        ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
    })
    .unwrap();
    let addr = server.addr.to_string();

    // The slot holder.
    let holder = RegistryClient::connect(&addr).unwrap();
    let tickets = holder.counter(DEFAULT_OBJECT).unwrap();
    tickets.take(1).unwrap();

    // Over capacity: the internal retry budget exhausts against a
    // full shard and surfaces the typed code, not a transport error.
    let err = RegistryClient::connect(&addr).unwrap_err();
    assert_eq!(code_of(&err), ErrorCode::AtCapacity, "rejection must be typed: {err:#}");
    assert!(err.to_string().contains("at capacity"), "human text preserved: {err}");

    // Capacity is transient: a second attempt that overlaps the slot
    // being released succeeds via the client's bounded retry.
    let addr2 = addr.clone();
    let waiter = std::thread::spawn(move || {
        let c = RegistryClient::connect(&addr2)?;
        c.counter(DEFAULT_OBJECT)?.take(1)
    });
    std::thread::sleep(std::time::Duration::from_millis(40));
    drop(tickets);
    drop(holder); // frees the only slot while the waiter is retrying
    let start = waiter.join().unwrap().expect("retry must win once the slot frees");
    assert_eq!(start, 1, "the waiter's grant follows the holder's");

    // Transport death is the other class: crash the server under a
    // connected client and the next op is `Io`, not `AtCapacity`.
    let victim = RegistryClient::connect(&addr).unwrap();
    let vtickets = victim.counter(DEFAULT_OBJECT).unwrap();
    server.crash();
    let err = vtickets.take(1).unwrap_err();
    assert_eq!(code_of(&err), ErrorCode::Io, "dead socket must be Io: {err:#}");
}
