//! End-to-end tests for the binary wire protocol: negotiation and
//! interop with JSON clients on one server, byte-identical behaviour
//! for clients that never negotiate, typed rejection of corrupt or
//! oversized frames, decode-time batch caps, and crash recovery of
//! byte-string payloads through the unified WAL framing.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use aggfunnels::config::ObjectManifest;
use aggfunnels::service::frame::{self, BinRequest, BinResponse, Item, WireDecode};
use aggfunnels::service::{serve, ErrorCode, PersistOpts, RegistryClient, ServeOpts};

/// Incremental frame reader over a raw test socket, buffering through
/// the same decoder the server and client use.
struct FrameReader {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl FrameReader {
    fn new(stream: TcpStream) -> Self {
        Self { stream, buf: Vec::new() }
    }

    /// The next frame payload, or `None` once the server closes.
    fn next(&mut self) -> Option<Vec<u8>> {
        let mut chunk = [0u8; 4096];
        loop {
            match frame::decode_wire_frame(&self.buf) {
                WireDecode::Frame { payload, consumed } => {
                    self.buf.drain(..consumed);
                    return Some(payload);
                }
                WireDecode::Partial => {
                    let n = self.stream.read(&mut chunk).unwrap();
                    if n == 0 {
                        return None;
                    }
                    self.buf.extend_from_slice(&chunk[..n]);
                }
                WireDecode::Bad(msg) => panic!("server sent a bad frame: {msg}"),
            }
        }
    }

    fn next_response(&mut self) -> Option<BinResponse> {
        self.next().map(|p| frame::decode_response(&p).unwrap())
    }
}

/// Connect raw, send the magic, and consume the hello frame.
fn negotiate_raw(addr: &str) -> FrameReader {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(&frame::WIRE_MAGIC).unwrap();
    let mut r = FrameReader::new(stream);
    match r.next_response().expect("hello frame") {
        BinResponse::Json(doc) => assert!(doc.contains("\"binary\":true"), "hello: {doc}"),
        other => panic!("unexpected hello {other:?}"),
    }
    r
}

fn send_frame(r: &mut FrameReader, req: &BinRequest) {
    let mut payload = Vec::new();
    frame::encode_request(req, &mut payload);
    let mut framed = Vec::new();
    frame::encode_frame(&payload, &mut framed);
    r.stream.write_all(&framed).unwrap();
}

#[test]
fn binary_and_json_clients_interoperate_on_one_server() {
    // Two shards so the binary handshake also has to skip the pushed
    // greeting line before the hello frame.
    let server = serve(&ServeOpts {
        objects: vec![ObjectManifest::new("jobs", "queue", "lcrq+elastic")],
        ..ServeOpts::sharded("127.0.0.1:0", 2, 4, 2)
    })
    .unwrap();
    let addr = server.addr.to_string();

    let bin = RegistryClient::connect_binary(&addr).unwrap();
    let json = RegistryClient::connect(&addr).unwrap();
    assert!(bin.is_binary() && !json.is_binary());

    // Items enqueued on the binary wire come back, typed, on the JSON
    // wire — same object, same item table.
    let bjobs = bin.queue("jobs").unwrap();
    let jjobs = json.queue("jobs").unwrap();
    assert_eq!(
        bjobs
            .enqueue_batch(vec![Item::Int(1), Item::Bytes(b"hello".to_vec()), Item::Int(2)])
            .unwrap(),
        3
    );
    assert_eq!(jjobs.dequeue_item().unwrap(), Some(Item::Int(1)));
    assert_eq!(jjobs.dequeue_item().unwrap(), Some(Item::Bytes(b"hello".to_vec())));
    assert_eq!(jjobs.dequeue().unwrap(), Some(2));

    // And the reverse direction.
    jjobs.enqueue_bytes(&[0x00, 0xff]).unwrap();
    assert_eq!(bjobs.dequeue_item().unwrap(), Some(Item::Bytes(vec![0x00, 0xff])));
    assert_eq!(bjobs.dequeue_item().unwrap(), None);

    // Counter grants stay disjoint across protocols.
    let btickets = bin.counter("tickets").unwrap();
    let jtickets = json.counter("tickets").unwrap();
    let b0 = btickets.take(5).unwrap();
    let j0 = jtickets.take(5).unwrap();
    assert!(b0 + 5 <= j0 || j0 + 5 <= b0, "overlapping grants {b0}/{j0}");
    assert_eq!(btickets.read().unwrap(), 10);
    assert_eq!(jtickets.read().unwrap(), 10);

    // The same pipelined batch produces the same typed responses on
    // either wire.
    for client in [&bin, &json] {
        let resps = client
            .call_many(&[
                BinRequest::Enqueue {
                    name: "jobs".to_string(),
                    items: vec![Item::Bytes(b"batch".to_vec())],
                },
                BinRequest::Dequeue { name: "jobs".to_string(), count: 4 },
                BinRequest::Take { name: "tickets".to_string(), count: 2, priority: false },
            ])
            .unwrap();
        assert_eq!(resps[0], BinResponse::Enqueued(1));
        assert_eq!(resps[1], BinResponse::Items(vec![Item::Bytes(b"batch".to_vec())]));
        assert!(matches!(resps[2], BinResponse::Start(_)), "got {:?}", resps[2]);
    }

    server.shutdown();
}

#[test]
fn non_negotiated_json_clients_see_byte_identical_responses() {
    // The compatibility pin: a plain JSON client (no magic preamble)
    // gets exactly the pre-binary wire, byte for byte.
    let server = serve(&ServeOpts {
        objects: vec![ObjectManifest::new("jobs", "queue", "lcrq+elastic")],
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    })
    .unwrap();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut ask = |line: &str| {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };
    assert_eq!(
        ask(r#"{"op":"take","name":"tickets","count":1}"#),
        "{\"count\":1,\"ok\":true,\"start\":0}\n"
    );
    assert_eq!(
        ask(r#"{"op":"read","name":"tickets"}"#),
        "{\"ok\":true,\"value\":1}\n"
    );
    assert_eq!(ask(r#"{"op":"enqueue","name":"jobs","item":7}"#), "{\"ok\":true}\n");
    assert_eq!(
        ask(r#"{"op":"dequeue","name":"jobs"}"#),
        "{\"item\":7,\"ok\":true}\n"
    );
    assert_eq!(
        ask(r#"{"op":"dequeue","name":"jobs"}"#),
        "{\"empty\":true,\"ok\":true}\n"
    );
    server.shutdown();
}

#[test]
fn corrupt_frames_get_a_typed_error_then_a_close() {
    let server = serve(&ServeOpts::fixed("127.0.0.1:0", 4, 2)).unwrap();
    let addr = server.addr.to_string();

    // A checksum-corrupted frame after a healthy request: the healthy
    // one is answered, the corrupt one draws a typed protocol error,
    // and the connection closes (no resync guessing on a binary
    // stream).
    let mut r = negotiate_raw(&addr);
    let take = BinRequest::Take { name: "tickets".to_string(), count: 1, priority: false };
    send_frame(&mut r, &take);
    assert_eq!(r.next_response(), Some(BinResponse::Start(0)));
    let mut payload = Vec::new();
    frame::encode_request(&take, &mut payload);
    let mut framed = Vec::new();
    frame::encode_frame(&payload, &mut framed);
    let last = framed.len() - 1;
    framed[last] ^= 0x01;
    r.stream.write_all(&framed).unwrap();
    match r.next_response() {
        Some(BinResponse::Err { code: ErrorCode::Protocol, msg }) => {
            assert!(msg.contains("checksum"), "{msg}");
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert_eq!(r.next(), None, "connection must close after a framing violation");

    // An oversized length prefix is rejected before any allocation.
    let mut r = negotiate_raw(&addr);
    let mut huge = ((frame::MAX_WIRE_FRAME + 1) as u32).to_le_bytes().to_vec();
    huge.extend_from_slice(&[0u8; 8]);
    r.stream.write_all(&huge).unwrap();
    match r.next_response() {
        Some(BinResponse::Err { code: ErrorCode::Protocol, msg }) => {
            assert!(msg.contains("exceeds"), "{msg}");
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert_eq!(r.next(), None);

    // A magic lead byte with a divergent tail is neither wire: typed
    // error, then close.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(&[0xA6, b'X', b'X', b'X', b'X', b'X', b'X', b'X']).unwrap();
    let mut r = FrameReader::new(stream);
    match r.next_response() {
        Some(BinResponse::Err { code: ErrorCode::Protocol, msg }) => {
            assert!(msg.contains("magic"), "{msg}");
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert_eq!(r.next(), None);

    server.shutdown();
}

#[test]
fn batch_caps_reject_at_decode_time_without_desyncing_the_pipeline() {
    let server = serve(&ServeOpts {
        objects: vec![ObjectManifest::new("jobs", "queue", "lcrq+elastic")],
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    })
    .unwrap();
    let bin = RegistryClient::connect_binary(&server.addr.to_string()).unwrap();

    // A pipelined batch with a cap-violating op in the middle: its
    // neighbours still execute and the error frame lands in position.
    let resps = bin
        .call_many(&[
            BinRequest::Take { name: "tickets".to_string(), count: 1, priority: false },
            BinRequest::Dequeue {
                name: "jobs".to_string(),
                count: (frame::MAX_BATCH_ITEMS + 1) as u32,
            },
            BinRequest::Take { name: "tickets".to_string(), count: 1, priority: false },
        ])
        .unwrap();
    assert_eq!(resps[0], BinResponse::Start(0));
    match &resps[1] {
        BinResponse::Err { code: ErrorCode::Protocol, msg } => {
            assert!(msg.contains("exceeds"), "{msg}");
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert_eq!(resps[2], BinResponse::Start(1));

    // Oversized single item: rejected at decode, before any enqueue.
    let resps = bin
        .call_many(&[BinRequest::Enqueue {
            name: "jobs".to_string(),
            items: vec![Item::Bytes(vec![0u8; frame::MAX_ITEM_BYTES + 1])],
        }])
        .unwrap();
    match &resps[0] {
        BinResponse::Err { code: ErrorCode::Protocol, .. } => {}
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert_eq!(bin.queue("jobs").unwrap().dequeue_item().unwrap(), None);

    server.shutdown();
}

#[test]
fn byte_payloads_survive_crash_recovery_exactly() {
    let dir = aggfunnels::util::scratch_dir("e2e-wire-crash");
    let dir_str = dir.to_string_lossy().into_owned();
    let serve_opts = |dir: &str| ServeOpts {
        persist: Some(PersistOpts::sync(dir.to_string())),
        objects: vec![ObjectManifest::new("jobs", "queue", "lcrq+elastic")],
        ..ServeOpts::fixed("127.0.0.1:0", 4, 2)
    };
    let server = serve(&serve_opts(&dir_str)).unwrap();
    let addr = server.addr.to_string();

    // Interleave byte payloads (length-varied, including empty-ish
    // single bytes) with integers, all acked synchronously.
    let bin = RegistryClient::connect_binary(&addr).unwrap();
    let jobs = bin.queue("jobs").unwrap();
    let mut expected: Vec<Item> = Vec::new();
    for k in 0..40u8 {
        let payload = vec![k; (k % 7 + 1) as usize];
        expected.push(Item::Bytes(payload.clone()));
        expected.push(Item::Int(1000 + k as u64));
        assert_eq!(
            jobs.enqueue_batch(vec![
                Item::Bytes(payload),
                Item::Int(1000 + k as u64),
            ])
            .unwrap(),
            2
        );
    }
    // Consume a prefix so recovery also replays dequeues.
    let taken = jobs.dequeue_batch(10).unwrap();
    assert_eq!(taken, expected[..10].to_vec());

    server.crash();

    let server = serve(&serve_opts(&dir_str)).unwrap();
    let bin = RegistryClient::connect_binary(&server.addr.to_string()).unwrap();
    let jobs = bin.queue("jobs").unwrap();
    let mut recovered = Vec::new();
    loop {
        let batch = jobs.dequeue_batch(16).unwrap();
        if batch.is_empty() {
            break;
        }
        recovered.extend(batch);
    }
    assert_eq!(
        recovered,
        expected[10..].to_vec(),
        "recovered queue must be the exact un-dequeued FIFO remainder"
    );

    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}
