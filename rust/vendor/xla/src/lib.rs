//! Offline stub of the `xla` (xla-rs) PJRT surface `aggfunnels` uses.
//!
//! The real crate links `xla_extension` (a multi-gigabyte native
//! build); this stub mirrors its API exactly but fails at the first
//! runtime entry point ([`PjRtClient::cpu`]) with a descriptive error.
//! Every caller in `aggfunnels` already handles that `Err` by falling
//! back to the in-process CPU oracle, so the crate builds and tests
//! fully offline. To execute the AOT JAX/Pallas artifacts for real,
//! point the `xla` path dependency in `rust/Cargo.toml` at an xla-rs
//! checkout — no `aggfunnels` source changes are needed.

use std::fmt;

/// Error type matching the real crate's `std::error::Error` surface.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: built against the offline xla stub (point rust/Cargo.toml's \
             `xla` path at an xla-rs checkout for PJRT execution)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry (mirror of xla-rs's
/// `NativeType` bound, reduced to what `aggfunnels` uses).
pub trait Element: Copy {}

impl Element for u32 {}
impl Element for u64 {}
impl Element for i32 {}
impl Element for i64 {}
impl Element for f32 {}
impl Element for f64 {}

/// Host-side literal value (constructible offline; conversions that
/// would require a device round-trip return errors).
#[derive(Clone, Debug, Default)]
pub struct Literal(());

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Element>(_values: &[T]) -> Literal {
        Literal(())
    }

    /// Rank-0 literal.
    pub fn scalar(_value: f64) -> Literal {
        Literal(())
    }

    /// Unpack a 1-element tuple.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::stub("Literal::to_tuple1"))
    }

    /// Unpack a 2-element tuple.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::stub("Literal::to_tuple2"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

/// Parsed HLO module (the text interchange format; see
/// `python/compile/aot.py`).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single runtime entry
/// point, so failing here guarantees no stubbed executable is ever
/// observable.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_point_fails_loudly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }

    #[test]
    fn literals_construct_offline() {
        let _ = Literal::vec1(&[1u64, 2, 3]);
        let _ = Literal::vec1(&[1i32]);
        let _ = Literal::scalar(1.5);
        assert!(Literal::default().to_vec::<u64>().is_err());
    }
}
