//! Combining Funnels baseline (Shavit & Zemach, JPDC 2000) — the
//! state-of-the-art software Fetch&Add the paper compares against.
//!
//! Structure (faithful to the published design): operations descend
//! through a series of *combining layers*, each an array of cells.
//! At every layer a thread swaps a pointer to its announcement node
//! into a randomly chosen cell, obtaining the node of whichever thread
//! visited that cell last; it then tries to *capture* that node with a
//! CAS, adopting its (subtree) sum and carrying it further down. At
//! the final layer the surviving delegate applies the combined sum to
//! the central variable with one hardware F&A, then distributes return
//! values back through the capture tree. The funnel is `⌈log p⌉ − 1`
//! layers deep with width halving per layer — the best-performing
//! configuration in the paper's evaluation (§4.3).
//!
//! Characteristics the paper highlights (and our benches reproduce):
//! many shared-variable accesses per operation ⇒ slow at low thread
//! counts; combining kicks in at high thread counts; high fairness due
//! to random cell choice.

use std::sync::atomic::{AtomicI64, AtomicPtr, AtomicU32, AtomicU64, Ordering};

use super::{delta_to_u64, BatchStats, FetchAddObject};
use crate::sync::{Backoff, CachePadded};
use crate::util::rng::Rng;

/// Node states. FREE nodes may be captured; LOCKED nodes are briefly
/// uncapturable while their owner mutates them; CAPTURED nodes belong
/// to another operation's subtree; DONE carries a delivered result.
const FREE: u32 = 0;
const LOCKED: u32 = 1;
const CAPTURED: u32 = 2;
const DONE: u32 = 3;

/// Per-thread announcement node. Lives for the lifetime of the object
/// (stale cell pointers may always be dereferenced).
struct Node {
    state: AtomicU32,
    /// Signed sum of this operation's delta plus all captured subtrees.
    sum: AtomicI64,
    /// This operation's own delta (distribution needs it separately).
    delta: AtomicI64,
    /// Result delivered by the capturer (valid once state == DONE).
    result: AtomicU64,
    /// Captured child nodes, in capture order. Owner-only.
    children: std::cell::UnsafeCell<Vec<*const Node>>,
}

unsafe impl Sync for Node {}

impl Node {
    fn new() -> Self {
        Self {
            state: AtomicU32::new(LOCKED), // uncapturable until an op starts
            sum: AtomicI64::new(0),
            delta: AtomicI64::new(0),
            result: AtomicU64::new(0),
            children: std::cell::UnsafeCell::new(Vec::new()),
        }
    }
}

/// Configuration of the funnel geometry.
#[derive(Clone, Debug)]
pub struct CombiningFunnelConfig {
    pub max_threads: usize,
    /// Number of combining layers (paper-best: ⌈log₂ p⌉ − 1).
    pub layers: usize,
    /// Width of the first layer (halved at each deeper layer).
    pub top_width: usize,
    /// Spins spent parked at each cell waiting for a collision.
    pub collision_window: u32,
    pub seed: u64,
}

impl CombiningFunnelConfig {
    /// The paper's best-performing geometry for `p` threads.
    pub fn new(p: usize) -> Self {
        let p = p.max(1);
        let log = (usize::BITS - (p - 1).leading_zeros()).max(1) as usize; // ceil(log2 p)
        Self {
            max_threads: p,
            layers: log.saturating_sub(1).max(1),
            top_width: (p / 2).max(1),
            collision_window: 32,
            seed: 0xC0DE_FA11_C0DE_FA11,
        }
    }
}

/// Combining Funnels Fetch&Add object.
pub struct CombiningFunnel {
    main: CachePadded<AtomicU64>,
    /// `layers[l]` is an array of cells holding node pointers.
    layers: Vec<Vec<CachePadded<AtomicPtr<Node>>>>,
    nodes: Vec<CachePadded<Node>>,
    rngs: Vec<CachePadded<std::cell::UnsafeCell<Rng>>>,
    cfg: CombiningFunnelConfig,
    /// F&As applied to `main` (for the batch-size metric).
    main_faas: CachePadded<AtomicU64>,
    ops: CachePadded<AtomicU64>,
}

unsafe impl Send for CombiningFunnel {}
unsafe impl Sync for CombiningFunnel {}

impl CombiningFunnel {
    pub fn new(max_threads: usize) -> Self {
        Self::with_config(CombiningFunnelConfig::new(max_threads))
    }

    pub fn with_config(cfg: CombiningFunnelConfig) -> Self {
        let mut layers = Vec::with_capacity(cfg.layers);
        let mut width = cfg.top_width.max(1);
        for _ in 0..cfg.layers {
            layers.push(
                (0..width).map(|_| CachePadded::new(AtomicPtr::new(std::ptr::null_mut()))).collect(),
            );
            width = (width / 2).max(1);
        }
        let nodes = (0..cfg.max_threads).map(|_| CachePadded::new(Node::new())).collect();
        let mut seed = Rng::new(cfg.seed);
        let rngs = (0..cfg.max_threads)
            .map(|t| CachePadded::new(std::cell::UnsafeCell::new(seed.fork(t as u64))))
            .collect();
        Self {
            main: CachePadded::new(AtomicU64::new(0)),
            layers,
            nodes,
            rngs,
            cfg,
            main_faas: CachePadded::new(AtomicU64::new(0)),
            ops: CachePadded::new(AtomicU64::new(0)),
        }
    }

    pub fn config(&self) -> &CombiningFunnelConfig {
        &self.cfg
    }

    /// Distribute results through `node`'s capture subtree: `node`'s
    /// own answer is `base`; children get consecutive prefix offsets.
    fn distribute(node: &Node, base: u64) -> u64 {
        let mut cur = base.wrapping_add(delta_to_u64(node.delta.load(Ordering::Relaxed)));
        let children = unsafe { &mut *node.children.get() };
        for &child_ptr in children.iter() {
            let child = unsafe { &*child_ptr };
            child.result.store(cur, Ordering::Relaxed);
            child.state.store(DONE, Ordering::Release);
            cur = cur.wrapping_add(child.sum.load(Ordering::Relaxed) as u64);
        }
        children.clear();
        base
    }

    fn fetch_add_slow(&self, tid: usize, delta: i64) -> u64 {
        let node = &*self.nodes[tid];
        let rng = unsafe { &mut *self.rngs[tid].get() };

        // Initialize my announcement and become capturable.
        unsafe { (*node.children.get()).clear() };
        node.delta.store(delta, Ordering::Relaxed);
        node.sum.store(delta, Ordering::Relaxed);
        node.state.store(FREE, Ordering::Release);

        for layer in &self.layers {
            // Park my node at a random cell of this layer.
            let cell = &layer[rng.below(layer.len() as u64) as usize];
            let prev = cell.swap(node as *const Node as *mut Node, Ordering::AcqRel);

            // Collision window: stay capturable for a moment.
            for _ in 0..self.cfg.collision_window {
                if node.state.load(Ordering::Acquire) == CAPTURED {
                    break;
                }
                std::hint::spin_loop();
            }

            // Lock myself so my subtree sum can't change under a capturer.
            if node
                .state
                .compare_exchange(FREE, LOCKED, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                // I was captured: wait for my result to be delivered,
                // then deliver to my own children.
                let mut backoff = Backoff::new();
                while node.state.load(Ordering::Acquire) != DONE {
                    backoff.snooze();
                }
                let base = node.result.load(Ordering::Relaxed);
                self.ops.fetch_add(1, Ordering::Relaxed);
                return Self::distribute(node, base);
            }

            // Try to combine with the node previously parked here.
            if !prev.is_null() && !std::ptr::eq(prev, node) {
                let other = unsafe { &*prev };
                if other
                    .state
                    .compare_exchange(FREE, CAPTURED, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    let captured_sum = other.sum.load(Ordering::Relaxed);
                    node.sum.fetch_add(captured_sum, Ordering::Relaxed);
                    unsafe { (*node.children.get()).push(other) };
                }
            }

            // Descend: become capturable again for the next layer.
            node.state.store(FREE, Ordering::Release);
        }

        // Survived all layers: take myself out of circulation and apply
        // the combined sum to the central variable.
        if node
            .state
            .compare_exchange(FREE, LOCKED, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            // Captured at the very last moment.
            let mut backoff = Backoff::new();
            while node.state.load(Ordering::Acquire) != DONE {
                backoff.snooze();
            }
            let base = node.result.load(Ordering::Relaxed);
            self.ops.fetch_add(1, Ordering::Relaxed);
            return Self::distribute(node, base);
        }

        let sum = node.sum.load(Ordering::Relaxed);
        let base = self.main.fetch_add(delta_to_u64(sum), Ordering::AcqRel);
        self.main_faas.fetch_add(1, Ordering::Relaxed);
        self.ops.fetch_add(1, Ordering::Relaxed);
        Self::distribute(node, base)
    }
}

impl FetchAddObject for CombiningFunnel {
    fn fetch_add(&self, tid: usize, delta: i64) -> u64 {
        if delta == 0 {
            return self.read(tid);
        }
        self.fetch_add_slow(tid, delta)
    }

    #[inline]
    fn read(&self, _tid: usize) -> u64 {
        self.main.load(Ordering::SeqCst)
    }

    #[inline]
    fn fetch_add_direct(&self, _tid: usize, delta: i64) -> u64 {
        self.main_faas.fetch_add(1, Ordering::Relaxed);
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.main.fetch_add(delta_to_u64(delta), Ordering::AcqRel)
    }

    #[inline]
    fn compare_and_swap(&self, _tid: usize, old: u64, new: u64) -> u64 {
        match self.main.compare_exchange(old, new, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(prev) => prev,
            Err(actual) => actual,
        }
    }

    #[inline]
    fn fetch_or(&self, _tid: usize, bits: u64) -> u64 {
        self.main.fetch_or(bits, Ordering::AcqRel)
    }

    fn max_threads(&self) -> usize {
        self.cfg.max_threads
    }

    fn batch_stats(&self) -> BatchStats {
        BatchStats {
            main_faas: self.main_faas.load(Ordering::Relaxed),
            ops: self.ops.load(Ordering::Relaxed),
            ..BatchStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_semantics() {
        let f = CombiningFunnel::new(1);
        assert_eq!(f.fetch_add(0, 5), 0);
        assert_eq!(f.fetch_add(0, -2), 5);
        assert_eq!(f.read(0), 3);
        assert_eq!(f.fetch_add(0, 0), 3);
    }

    #[test]
    fn geometry_matches_paper_best() {
        let cfg = CombiningFunnelConfig::new(176);
        assert_eq!(cfg.layers, 7, "ceil(log2 176) - 1 = 7");
        let cfg = CombiningFunnelConfig::new(2);
        assert_eq!(cfg.layers, 1);
    }

    #[test]
    fn concurrent_fetch_inc_dense() {
        let p = 8;
        let f = Arc::new(CombiningFunnel::new(p));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..2_000).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..(p as u64 * 2_000)).collect::<Vec<_>>());
        assert_eq!(f.read(0), p as u64 * 2_000);
    }

    #[test]
    fn concurrent_mixed_signs_sum_conserved() {
        let p = 6;
        let f = Arc::new(CombiningFunnel::new(p));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for i in 0i64..3_000 {
                        f.fetch_add(tid, if i % 3 == 0 { -5 } else { 4 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let per: i64 = (0..3_000).map(|i| if i % 3 == 0 { -5 } else { 4 }).sum();
        assert_eq!(f.read(0) as i64, 6 * per);
    }

    #[test]
    fn combining_happens_under_contention() {
        let p = 8;
        let f = Arc::new(CombiningFunnel::new(p));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for _ in 0..2_000 {
                        f.fetch_add(tid, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = f.batch_stats();
        assert_eq!(s.ops, p as u64 * 2_000);
        assert!(s.main_faas <= s.ops);
    }
}
