//! Aggregating Funnels — the paper's Algorithm 1.
//!
//! A strongly-linearizable `Fetch&Add` built from `Load`, `Store` and
//! hardware `F&A` only. One principal variable `Main` holds the
//! object's value; `2m` *Aggregators* (m for positive deltas, m for
//! negative) absorb concurrent operations into *batches*. Each
//! operation performs a single F&A on its Aggregator's `value`; the
//! operation that starts a batch (the *delegate*) applies the whole
//! batch to `Main` with one F&A and publishes a `Batch` record from
//! which the remaining operations compute their own return values
//! (Lemma 3.4: `mainBefore + (aBefore − batch.before) · sgn(df)`).
//!
//! The overflow path (the paper's cyan code) is implemented: when an
//! Aggregator's `value` passes `threshold`, the delegate *retires* it —
//! replacing it in the `Agg` array with a fresh Aggregator and setting
//! its `final` field so stragglers restart — bounding each Aggregator's
//! `value` below 2⁶⁴ provided every |delta| < 2⁶³/p.
//!
//! Memory reclamation (§3.1.2) uses the crate's epoch-based
//! reclamation: a `Batch` is retired when a newer batch replaces it as
//! `last`, an Aggregator when it is replaced in `Agg`; Θ(m) objects are
//! live at any time.
//!
//! This implementation is generic over the `Main` cell ([`MainCell`])
//! so the §3.2 recursive construction — replacing `Main` with another
//! Aggregating Funnel — is expressed as `AggFunnel<AggFunnel<...>>`
//! (see [`super::recursive`]).

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};

use super::choose::Choose;
use super::{delta_to_u64, BatchStats, FetchAddObject};
use crate::ebr;
use crate::sync::{Backoff, CachePadded, CasCtl, RetryPolicy};
use crate::util::rng::Rng;

/// `final` field value meaning "Aggregator still in use" (the paper's ∞).
///
/// Shared with [`super::elastic`], which reuses the same Aggregator and
/// Batch memory layout for its resizable variant.
pub(super) const FINAL_INFINITY: u64 = u64::MAX;

/// A batch of operations applied to an Aggregator (all fields
/// immutable after publication; `previous` links the Batch list).
pub(super) struct Batch {
    /// Aggregator's `value` before the batch (`before` in the paper).
    pub(super) before: u64,
    /// Aggregator's `value` after the batch.
    pub(super) after: u64,
    /// Value of `Main` just before the batch was applied to it.
    pub(super) main_before: u64,
    /// Previous Batch in the Aggregator's list (null for the sentinel).
    pub(super) previous: *mut Batch,
}

// Safety: a Batch is immutable after publication; the raw `previous`
// pointer is only dereferenced by EBR-pinned readers, and Batch's drop
// does not follow it. Sending a retired Batch to the EBR domain (which
// may free it from another thread) is therefore sound.
unsafe impl Send for Batch {}

/// The rarely-written, waiter-read pair of an Aggregator. `last` and
/// `final` are always read together in the wait loop (lines 23–24) and
/// written together by retiring delegates, so they share a cache line
/// — one transfer serves both reads (§Perf: −1 line touch per op) —
/// while the RMW-hot `value` stays on its own line.
pub(super) struct AggregatorTail {
    /// Most recent Batch applied to `Main` from this Aggregator.
    pub(super) last: AtomicPtr<Batch>,
    /// `value` after the final batch once retired, else ∞.
    pub(super) final_value: AtomicU64,
}

/// An Aggregator: funnels a stream of operations into batches.
pub(super) struct Aggregator {
    /// Sum of |delta| of all operations applied here (only grows).
    pub(super) value: CachePadded<AtomicU64>,
    pub(super) tail: CachePadded<AggregatorTail>,
}

impl Aggregator {
    pub(super) fn boxed() -> Box<Aggregator> {
        let sentinel = Box::into_raw(Box::new(Batch {
            before: 0,
            after: 0,
            main_before: 0,
            previous: std::ptr::null_mut(),
        }));
        Box::new(Aggregator {
            value: CachePadded::new(AtomicU64::new(0)),
            tail: CachePadded::new(AggregatorTail {
                last: AtomicPtr::new(sentinel),
                final_value: AtomicU64::new(FINAL_INFINITY),
            }),
        })
    }
}

impl Drop for Aggregator {
    fn drop(&mut self) {
        // Only the current `last` Batch is still owned by the
        // Aggregator — every older Batch was individually retired when
        // it was replaced as `last`.
        let last = *self.tail.last.get_mut();
        if !last.is_null() {
            drop(unsafe { Box::from_raw(last) });
        }
    }
}

/// The `Main` cell an [`AggFunnel`] applies batches to. Implemented by
/// a plain atomic word ([`AtomicMain`]) and by `AggFunnel` itself
/// (giving the recursive construction of §3.2).
pub trait MainCell: Send + Sync {
    /// F&A of a signed delta (mod 2⁶⁴); returns the previous value.
    fn apply_add(&self, tid: usize, delta: i64) -> u64;
    /// Linearizable read.
    fn load(&self, tid: usize) -> u64;
    /// CAS; returns the witnessed value.
    fn cas(&self, tid: usize, old: u64, new: u64) -> u64;
    /// Atomic OR; returns the previous value.
    fn or(&self, tid: usize, bits: u64) -> u64;
}

/// A cache-padded atomic word as the principal variable.
pub struct AtomicMain(CachePadded<AtomicU64>);

impl AtomicMain {
    pub fn new(initial: u64) -> Self {
        Self(CachePadded::new(AtomicU64::new(initial)))
    }
}

impl MainCell for AtomicMain {
    #[inline]
    fn apply_add(&self, _tid: usize, delta: i64) -> u64 {
        self.0.fetch_add(delta_to_u64(delta), Ordering::AcqRel)
    }

    #[inline]
    fn load(&self, _tid: usize) -> u64 {
        self.0.load(Ordering::SeqCst)
    }

    #[inline]
    fn cas(&self, _tid: usize, old: u64, new: u64) -> u64 {
        match self.0.compare_exchange(old, new, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(prev) => prev,
            Err(actual) => actual,
        }
    }

    #[inline]
    fn or(&self, _tid: usize, bits: u64) -> u64 {
        self.0.fetch_or(bits, Ordering::AcqRel)
    }
}

/// Construction parameters for an [`AggFunnel`].
#[derive(Clone, Debug)]
pub struct AggFunnelConfig {
    /// Maximum number of threads (`p`); thread ids are `0..p`.
    pub max_threads: usize,
    /// Aggregators per sign (`m`). The paper's best default is 6.
    pub aggregators: usize,
    /// Aggregator retirement threshold (paper default 2⁶³). Tests use
    /// tiny values to exercise the overflow path.
    pub threshold: u64,
    /// Aggregator selection policy.
    pub choose: Choose,
    /// Threads with `tid < direct_threads` are high-priority: their
    /// `fetch_add` goes straight to `Main` (§4.4's AGGFUNNEL-(m,d)).
    pub direct_threads: usize,
    /// Seed for the per-thread RNGs used by `Choose::Random`.
    pub seed: u64,
    /// Recording mode (for the linearizability verifier): every
    /// funnelled operation is logged and Batch records are kept alive
    /// so [`AggFunnel::extract_history`] can reconstruct the full
    /// batch history after the run. Costs memory ∝ history length.
    pub record: bool,
    /// Retry policy pacing the overflow-restart loop (line 21 re-reads
    /// after an Aggregator retirement). Swappable at runtime through
    /// [`FetchAddObject::set_cas_policy`].
    pub cas_policy: RetryPolicy,
}

impl AggFunnelConfig {
    /// The paper's default configuration: AGGFUNNEL-6, static even
    /// assignment, threshold 2⁶³, no priority threads.
    pub fn new(max_threads: usize) -> Self {
        Self {
            max_threads: max_threads.max(1),
            aggregators: 6,
            threshold: 1 << 63,
            choose: Choose::StaticEven,
            direct_threads: 0,
            seed: 0x5EED_A66F,
            record: false,
            cas_policy: RetryPolicy::default(),
        }
    }

    pub fn with_aggregators(mut self, m: usize) -> Self {
        self.aggregators = m.max(1);
        self
    }

    pub fn with_threshold(mut self, t: u64) -> Self {
        self.threshold = t;
        self
    }

    pub fn with_choose(mut self, c: Choose) -> Self {
        self.choose = c;
        self
    }

    pub fn with_direct_threads(mut self, d: usize) -> Self {
        self.direct_threads = d;
        self
    }

    pub fn with_cas_policy(mut self, p: RetryPolicy) -> Self {
        self.cas_policy = p;
        self
    }

    /// Enable history recording (verifier mode). Forces an effectively
    /// infinite threshold so the batch chains stay walkable.
    pub fn with_recording(mut self) -> Self {
        self.record = true;
        self.threshold = u64::MAX;
        self
    }
}

/// One recorded funnelled operation (verifier mode).
#[derive(Clone, Copy, Debug)]
pub struct OpRecord {
    /// Index into the `Agg` array (sign encoded: `>= m` is negative).
    pub agg_index: u32,
    /// Result of the op's F&A on the Aggregator's `value`.
    pub a_before: u64,
    /// The operation's |delta|.
    pub magnitude: u64,
    /// The value the operation returned to its caller.
    pub result: u64,
}

/// Per-thread scratch state (RNG for random choice, batch counters).
struct ThreadScratch {
    rng: Rng,
    /// Batches this thread applied to Main as a delegate (+ direct ops).
    main_faas: u64,
    /// Fetch&Add operations this thread completed through the funnel.
    ops: u64,
    /// Recorded operations (verifier mode only).
    records: Vec<OpRecord>,
}

/// Aggregating Funnels (paper Algorithm 1), generic over the `Main`
/// cell for the recursive construction.
pub struct AggFunnel<M: MainCell = AtomicMain> {
    main: M,
    /// `Agg[0..m)` for positive deltas, `Agg[m..2m)` for negative.
    agg: Vec<CachePadded<AtomicPtr<Aggregator>>>,
    cfg: AggFunnelConfig,
    /// Paces the overflow-restart loop in `fetch_add_funnel`.
    cas: CasCtl,
    ebr: ebr::Domain,
    scratch: Vec<CachePadded<std::cell::UnsafeCell<ThreadScratch>>>,
}

unsafe impl<M: MainCell> Send for AggFunnel<M> {}
unsafe impl<M: MainCell> Sync for AggFunnel<M> {}

impl AggFunnel<AtomicMain> {
    /// Build with the paper's defaults (`AGGFUNNEL-6`) for `p` threads.
    pub fn new(max_threads: usize) -> Self {
        Self::with_config(AggFunnelConfig::new(max_threads))
    }

    /// Build with an explicit configuration and a plain atomic `Main`.
    pub fn with_config(cfg: AggFunnelConfig) -> Self {
        Self::with_main(cfg, AtomicMain::new(0))
    }
}

impl<M: MainCell> AggFunnel<M> {
    /// Build with an explicit `Main` cell (the recursive construction
    /// passes another `AggFunnel` here).
    pub fn with_main(cfg: AggFunnelConfig, main: M) -> Self {
        let m2 = cfg.aggregators * 2;
        let agg = (0..m2)
            .map(|_| CachePadded::new(AtomicPtr::new(Box::into_raw(Aggregator::boxed()))))
            .collect();
        let mut seed_rng = Rng::new(cfg.seed);
        let scratch = (0..cfg.max_threads)
            .map(|t| {
                CachePadded::new(std::cell::UnsafeCell::new(ThreadScratch {
                    rng: seed_rng.fork(t as u64),
                    main_faas: 0,
                    ops: 0,
                    records: Vec::new(),
                }))
            })
            .collect();
        let ebr = ebr::Domain::new(cfg.max_threads);
        let cas = CasCtl::new(cfg.cas_policy);
        Self { main, agg, cfg, cas, ebr, scratch }
    }

    pub fn config(&self) -> &AggFunnelConfig {
        &self.cfg
    }

    /// Number of Aggregators per sign (`m`).
    pub fn aggregators_per_sign(&self) -> usize {
        self.cfg.aggregators
    }

    #[inline]
    fn scratch(&self, tid: usize) -> &mut ThreadScratch {
        // Safety: `tid` is owned by exactly one OS thread (trait contract).
        unsafe { &mut *self.scratch[tid].get() }
    }

    /// ChooseAggregator (line 20): index into `agg`, honouring sign.
    #[inline]
    fn choose_index(&self, tid: usize, positive: bool) -> usize {
        let m = self.cfg.aggregators;
        let scratch = self.scratch(tid);
        let g = self.cfg.choose.pick(tid, m, || scratch.rng.next_u64());
        if positive {
            g
        } else {
            m + g
        }
    }

    /// The funnelled Fetch&Add path (lines 20–37).
    fn fetch_add_funnel(&self, tid: usize, delta: i64) -> u64 {
        let positive = delta > 0;
        let magnitude = delta.unsigned_abs();
        let index = self.choose_index(tid, positive);
        let slot = &self.agg[index];
        let guard = self.ebr.pin(tid);
        let mut retry = self.cas.retry(tid as u64);

        // "go to line 21" (overflow restart) re-reads Agg[index].
        loop {
            // Line 21: a ← Agg[index].
            let a_ptr = slot.load(Ordering::Acquire);
            debug_assert!(!a_ptr.is_null());
            let a = unsafe { &*a_ptr };

            // Line 22: register in a batch with a single F&A.
            let a_before = a.value.fetch_add(magnitude, Ordering::AcqRel);

            // Lines 23–24 (shared with the elastic funnel).
            let last_ptr = await_batch(a, a_before);
            if last_ptr.is_null() {
                // Aggregator overflowed; Agg[index] already holds a
                // fresh Aggregator (the delegate replaced it *before*
                // setting `final`). Restart there with the full delta,
                // paced like a failed CAS — restarts cluster exactly
                // when a retirement storm is in progress.
                retry.on_fail();
                continue;
            }
            let batch = unsafe { &*last_ptr };
            retry.on_success();

            return if batch.after == a_before {
                // Lines 26–33: I am the delegate of the next batch.
                let result =
                    self.run_delegate(tid, index, a_ptr, last_ptr, a_before, positive);
                if self.cfg.record {
                    self.scratch(tid).records.push(OpRecord {
                        agg_index: index as u32,
                        a_before,
                        magnitude,
                        result,
                    });
                }
                result
            } else {
                // Lines 34–37: my batch is already linked; find it and
                // derive my return value.
                let result = non_delegate_result(batch, a_before, positive);
                let s = self.scratch(tid);
                s.ops += 1;
                if self.cfg.record {
                    s.records.push(OpRecord {
                        agg_index: index as u32,
                        a_before,
                        magnitude,
                        result,
                    });
                }
                drop(guard);
                result
            };
        }
    }

    /// Delegate path (lines 26–33): close the batch, apply it to Main,
    /// publish the Batch record, retire the Aggregator on overflow.
    fn run_delegate(
        &self,
        tid: usize,
        index: usize,
        a_ptr: *mut Aggregator,
        last_ptr: *mut Batch,
        a_before: u64,
        positive: bool,
    ) -> u64 {
        let a = unsafe { &*a_ptr };

        // Line 27: read the Aggregator's value — this closes the batch.
        let a_after = a.value.load(Ordering::Acquire);
        debug_assert!(a_after > a_before);
        let sum = a_after.wrapping_sub(a_before);

        // Line 28: apply the whole batch to Main with one F&A.
        // (`sum < 2^63` because threshold ≤ 2^63 and |delta| < 2^63/p.)
        let signed_sum = if positive { sum as i64 } else { (sum as i64).wrapping_neg() };
        let main_before = self.main.apply_add(tid, signed_sum);

        // Lines 29–31: retire the Aggregator if it crossed the
        // threshold. Order is load-bearing: replace in Agg first, then
        // set `final` — so any operation that sees `final` set will
        // find the fresh Aggregator on restart.
        let retired = a_after >= self.cfg.threshold;
        if retired {
            let fresh = Box::into_raw(Aggregator::boxed());
            self.agg[index].store(fresh, Ordering::Release);
            a.tail.final_value.store(a_after, Ordering::Release);
        }

        // Line 32: publish the Batch record; waiters exit their loops.
        let new_batch = Box::into_raw(Box::new(Batch {
            before: a_before,
            after: a_after,
            main_before,
            previous: last_ptr,
        }));
        a.tail.last.store(new_batch, Ordering::Release);

        // §3.1.2 reclamation: the replaced Batch is no longer pointed
        // to by the Aggregator (only by `previous` links that pinned
        // stragglers may still traverse) — retire it. Likewise the
        // Aggregator itself if we replaced it in Agg. In verifier mode
        // the chain is kept alive for `extract_history`.
        if !self.cfg.record {
            self.ebr.retire_box(tid, unsafe { Box::from_raw(last_ptr) });
            if retired {
                self.ebr.retire_box(tid, unsafe { Box::from_raw(a_ptr) });
            }
        }

        let s = self.scratch(tid);
        s.main_faas += 1;
        s.ops += 1;
        main_before // line 33
    }

    /// Objects *owned* by the funnel right now: its 2m Aggregators and
    /// their current `last` Batches (everything else has been handed to
    /// EBR). This is the Θ(m) bound of §3.1.2. (Older batches linked
    /// via `previous` are retired garbage and must not be traversed
    /// outside a pinned operation, so they are not counted here.)
    pub fn debug_owned_objects(&self) -> usize {
        2 * self.agg.len() // one Aggregator + one last Batch per slot
    }

    /// Reclamation counters summed over threads: `(retired, freed)`.
    pub fn debug_ebr_stats(&self) -> (u64, u64) {
        let mut retired = 0;
        let mut freed = 0;
        for tid in 0..self.cfg.max_threads {
            let (r, f) = self.ebr.stats(tid);
            retired += r;
            freed += f;
        }
        (retired, freed)
    }
}

impl<M: MainCell> FetchAddObject for AggFunnel<M> {
    fn fetch_add(&self, tid: usize, delta: i64) -> u64 {
        // Line 19: Fetch&Add(0) is a Read.
        if delta == 0 {
            return self.read(tid);
        }
        // §4.4: high-priority threads bypass the funnel.
        if tid < self.cfg.direct_threads {
            return self.fetch_add_direct(tid, delta);
        }
        self.fetch_add_funnel(tid, delta)
    }

    #[inline]
    fn read(&self, tid: usize) -> u64 {
        self.main.load(tid) // lines 16–17
    }

    #[inline]
    fn fetch_add_direct(&self, tid: usize, delta: i64) -> u64 {
        let s = self.scratch(tid);
        s.main_faas += 1;
        s.ops += 1;
        self.main.apply_add(tid, delta) // lines 38–39
    }

    #[inline]
    fn compare_and_swap(&self, tid: usize, old: u64, new: u64) -> u64 {
        self.main.cas(tid, old, new) // lines 40–41
    }

    #[inline]
    fn fetch_or(&self, tid: usize, bits: u64) -> u64 {
        self.main.or(tid, bits)
    }

    fn max_threads(&self) -> usize {
        self.cfg.max_threads
    }

    fn batch_stats(&self) -> BatchStats {
        let mut stats = BatchStats::default();
        for s in &self.scratch {
            let s = unsafe { &*s.get() };
            stats.main_faas += s.main_faas;
            stats.ops += s.ops;
        }
        stats
    }

    fn set_cas_policy(&self, policy: RetryPolicy) {
        self.cas.set(policy);
    }

    fn cas_policy(&self) -> Option<RetryPolicy> {
        Some(self.cas.get())
    }
}

impl<M: MainCell> Drop for AggFunnel<M> {
    fn drop(&mut self) {
        for slot in &self.agg {
            free_aggregator(slot.load(Ordering::Relaxed), self.cfg.record);
        }
        // Retired Aggregators/Batches are freed by the EBR domain drop.
    }
}

/// The lines 23–24 wait loop, shared by the static and elastic
/// funnels: spin until my batch has been added to `a`'s list, or until
/// I can start the next batch — returning the `last` Batch pointer —
/// or until the Aggregator is retired under me, returning null (the
/// caller restarts with the full delta). Read order is load-bearing
/// (§3.1.1): `a.last` first, `a.final` second.
#[inline]
pub(super) fn await_batch(a: &Aggregator, a_before: u64) -> *mut Batch {
    let mut backoff = Backoff::new();
    loop {
        let last_ptr = a.tail.last.load(Ordering::Acquire);
        let last = unsafe { &*last_ptr };
        if last.after >= a_before {
            if a_before >= a.tail.final_value.load(Ordering::Acquire) {
                return std::ptr::null_mut(); // line 24: restart
            }
            return last_ptr;
        }
        if a_before >= a.tail.final_value.load(Ordering::Acquire) {
            return std::ptr::null_mut(); // line 24: restart
        }
        backoff.snooze();
    }
}

/// Non-delegate result computation (lines 35–37), shared by the static
/// and elastic funnels.
#[inline]
pub(super) fn non_delegate_result(mut batch: &Batch, a_before: u64, positive: bool) -> u64 {
    // Line 35–36: walk back to the Batch containing me
    // (97% of the time `batch` already is it — paper §3.1).
    while batch.before > a_before {
        debug_assert!(!batch.previous.is_null());
        batch = unsafe { &*batch.previous };
    }
    debug_assert!(batch.before <= a_before && a_before < batch.after);
    // Line 37: mainBefore + (aBefore − batch.before) · sgn(df).
    let offset = a_before.wrapping_sub(batch.before);
    if positive {
        batch.main_before.wrapping_add(offset)
    } else {
        batch.main_before.wrapping_sub(offset)
    }
}

/// Free an owned Aggregator at drop time, shared by the static and
/// elastic funnels. In recording mode the whole Batch chain was kept
/// alive: free every Batch behind `last`, then let the Aggregator's
/// own drop free `last` itself.
///
/// Caller must own `p` exclusively (drop-time only).
pub(super) fn free_aggregator(p: *mut Aggregator, record: bool) {
    if p.is_null() {
        return;
    }
    if record {
        unsafe {
            let a = &*p;
            let last = a.tail.last.load(Ordering::Relaxed);
            if !last.is_null() {
                let mut b = (*last).previous;
                while !b.is_null() {
                    let prev = (*b).previous;
                    drop(Box::from_raw(b));
                    b = prev;
                }
            }
        }
    }
    drop(unsafe { Box::from_raw(p) });
}

impl<M: MainCell> AggFunnel<M> {
    /// Reconstruct the full batch history of a recording-mode run.
    ///
    /// Must be called after every worker thread has finished (it walks
    /// the Batch chains and the per-thread op logs unsynchronized).
    /// Returns the history in oracle layout plus, aligned with it, the
    /// value each operation actually returned — ready for
    /// [`crate::runtime::OracleRuntime::batch_returns`] comparison.
    ///
    /// Panics if the funnel was not built `with_recording()`, and
    /// asserts Invariant 3.1 (each Aggregator's batch list is
    /// contiguous: `previous.after == before`, strictly increasing)
    /// while walking.
    pub fn extract_history(&self) -> (crate::runtime::BatchHistory, Vec<u64>) {
        assert!(self.cfg.record, "extract_history requires recording mode");
        // Gather all op records, bucketed per Aggregator index.
        let mut per_agg: Vec<Vec<OpRecord>> = vec![Vec::new(); self.agg.len()];
        for s in &self.scratch {
            let s = unsafe { &*s.get() };
            for r in &s.records {
                per_agg[r.agg_index as usize].push(*r);
            }
        }
        let mut history = crate::runtime::BatchHistory::default();
        let mut recorded = Vec::new();
        for (index, slot) in self.agg.iter().enumerate() {
            let mut ops = std::mem::take(&mut per_agg[index]);
            if ops.is_empty() {
                continue;
            }
            ops.sort_by_key(|r| r.a_before);
            let sign: i32 = if index < self.cfg.aggregators { 1 } else { -1 };
            // Collect the chain oldest-first.
            let a = unsafe { &*slot.load(Ordering::Acquire) };
            let mut chain = Vec::new();
            let mut b = a.tail.last.load(Ordering::Acquire);
            while !b.is_null() {
                chain.push(unsafe { &*b });
                b = unsafe { (*b).previous };
            }
            chain.reverse();
            // Invariant 3.1 checks + op assignment.
            let mut op_iter = ops.iter().peekable();
            for w in chain.windows(2) {
                assert_eq!(w[0].after, w[1].before, "Invariant 3.1: contiguity violated");
            }
            for batch in chain.iter().skip(1) {
                // skip the sentinel (before == after == 0)
                assert!(batch.after > batch.before, "Invariant 3.1: empty batch");
                let mut deltas = Vec::new();
                let mut cursor = batch.before;
                while let Some(r) = op_iter.peek() {
                    if r.a_before >= batch.after {
                        break;
                    }
                    assert_eq!(
                        r.a_before, cursor,
                        "ops within a batch must tile it exactly"
                    );
                    deltas.push(r.magnitude);
                    recorded.push(r.result);
                    cursor = cursor.wrapping_add(r.magnitude);
                    op_iter.next();
                }
                assert_eq!(cursor, batch.after, "batch sum mismatch (Invariant 3.1)");
                history.push_batch(batch.main_before, sign, &deltas);
            }
            assert!(op_iter.next().is_none(), "op not covered by any batch");
        }
        (history, recorded)
    }
}

/// `AggFunnel` can itself serve as the `Main` cell of an outer funnel
/// (§3.2's recursive construction).
impl<M: MainCell> MainCell for AggFunnel<M> {
    #[inline]
    fn apply_add(&self, tid: usize, delta: i64) -> u64 {
        self.fetch_add(tid, delta)
    }

    #[inline]
    fn load(&self, tid: usize) -> u64 {
        self.read(tid)
    }

    #[inline]
    fn cas(&self, tid: usize, old: u64, new: u64) -> u64 {
        self.compare_and_swap(tid, old, new)
    }

    #[inline]
    fn or(&self, tid: usize, bits: u64) -> u64 {
        self.fetch_or(tid, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_matches_hardware_semantics() {
        let f = AggFunnel::new(1);
        assert_eq!(f.fetch_add(0, 5), 0);
        assert_eq!(f.fetch_add(0, 3), 5);
        assert_eq!(f.fetch_add(0, -2), 8);
        assert_eq!(f.read(0), 6);
        assert_eq!(f.fetch_add(0, 0), 6, "Fetch&Add(0) is a Read");
    }

    #[test]
    fn rmw_operations_hit_main() {
        let f = AggFunnel::new(2);
        f.fetch_add(0, 10);
        assert_eq!(f.compare_and_swap(0, 10, 99), 10);
        assert_eq!(f.read(1), 99);
        assert_eq!(f.fetch_or(1, 0b100), 99);
        assert_eq!(f.read(0), 99 | 0b100);
    }

    #[test]
    fn direct_path_counts_and_returns() {
        let f = AggFunnel::with_config(AggFunnelConfig::new(2).with_direct_threads(1));
        assert_eq!(f.fetch_add(0, 7), 0); // tid 0 is high-priority → direct
        assert_eq!(f.fetch_add(1, 1), 7);
        let stats = f.batch_stats();
        assert_eq!(stats.ops, 2);
    }

    #[test]
    fn wrapping_negative_to_below_zero() {
        let f = AggFunnel::new(1);
        assert_eq!(f.fetch_add(0, -3), 0);
        assert_eq!(f.read(0), (-3i64) as u64);
        assert_eq!(f.fetch_add(0, 3), (-3i64) as u64);
        assert_eq!(f.read(0), 0);
    }

    #[test]
    fn concurrent_sum_conserved_mixed_signs() {
        let p = 8;
        let f = Arc::new(AggFunnel::with_config(
            AggFunnelConfig::new(p).with_aggregators(2),
        ));
        let per_thread = 4_000i64;
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        let d = if (tid + i as usize) % 4 == 0 { -3 } else { 5 };
                        f.fetch_add(tid, d);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut expected = 0i64;
        for tid in 0..p {
            for i in 0..per_thread {
                expected += if (tid + i as usize) % 4 == 0 { -3 } else { 5 };
            }
        }
        assert_eq!(f.read(0), expected as u64);
    }

    #[test]
    fn fetch_inc_results_distinct_and_dense() {
        // All-increment workload: the multiset of returned values must
        // be exactly {0, 1, ..., N-1} — the classic F&I correctness probe.
        let p = 6;
        let per_thread = 3_000usize;
        let f = Arc::new(AggFunnel::with_config(
            AggFunnelConfig::new(p).with_aggregators(3),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..per_thread).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let n = p * per_thread;
        assert_eq!(all, (0..n as u64).collect::<Vec<_>>());
        assert_eq!(f.read(0), n as u64);
    }

    #[test]
    fn overflow_path_retires_aggregators() {
        // Tiny threshold forces constant Aggregator retirement; the
        // object must stay linearizable throughout.
        let p = 4;
        let per_thread = 2_000usize;
        let f = Arc::new(AggFunnel::with_config(
            AggFunnelConfig::new(p).with_aggregators(1).with_threshold(64),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..per_thread).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let n = p * per_thread;
        assert_eq!(all, (0..n as u64).collect::<Vec<_>>(), "lost or duplicated a ticket");
    }

    #[test]
    fn batch_stats_show_combining_under_concurrency() {
        let p = 8;
        let f = Arc::new(AggFunnel::with_config(
            AggFunnelConfig::new(p).with_aggregators(1),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for _ in 0..2_000 {
                        f.fetch_add(tid, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = f.batch_stats();
        assert_eq!(stats.ops, 8 * 2_000);
        assert!(stats.main_faas <= stats.ops);
        assert!(stats.main_faas > 0);
    }

    #[test]
    fn random_choose_policy_works() {
        let p = 4;
        let f = Arc::new(AggFunnel::with_config(
            AggFunnelConfig::new(p).with_aggregators(3).with_choose(Choose::Random),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..2_000).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..(p as u64 * 2_000)).collect::<Vec<_>>());
    }

    #[test]
    fn overflow_path_correct_under_every_retry_policy() {
        // The retirement storm (tiny threshold) is the loop the retry
        // policies pace; every policy must leave a dense ticket range.
        for policy in RetryPolicy::ALL {
            let p = 4;
            let per_thread = 500usize;
            let f = Arc::new(AggFunnel::with_config(
                AggFunnelConfig::new(p)
                    .with_aggregators(1)
                    .with_threshold(32)
                    .with_cas_policy(policy),
            ));
            assert_eq!(f.cas_policy(), Some(policy));
            let handles: Vec<_> = (0..p)
                .map(|tid| {
                    let f = Arc::clone(&f);
                    std::thread::spawn(move || {
                        (0..per_thread).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                    })
                })
                .collect();
            let mut all: Vec<u64> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            all.sort_unstable();
            let n = (p * per_thread) as u64;
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "policy {policy:?}");
        }
    }

    #[test]
    fn cas_policy_swaps_live() {
        let f = AggFunnel::new(2);
        assert_eq!(f.cas_policy(), Some(RetryPolicy::default()));
        f.set_cas_policy(RetryPolicy::None);
        assert_eq!(f.cas_policy(), Some(RetryPolicy::None));
        f.fetch_add(0, 1); // still functional after the swap
        assert_eq!(f.read(1), 1);
    }

    #[test]
    fn owned_objects_theta_m() {
        let f = AggFunnel::new(2);
        for i in 0..100 {
            f.fetch_add(0, 1 + i);
        }
        // §3.1.2: Θ(m) non-retired objects regardless of history length.
        assert_eq!(f.debug_owned_objects(), 2 * 2 * 6);
        let (retired, _freed) = f.debug_ebr_stats();
        assert!(retired >= 100, "each applied batch retires its predecessor");
    }
}
