//! Flat-combining Fetch&Add — a lock-based combining baseline in the
//! style the paper cites as prior software combining ([12] Fatourou &
//! Kallimanis, CC-Synch; Hendler et al.'s flat combining).
//!
//! Every thread publishes its delta in a per-thread announcement slot;
//! whichever thread acquires the combiner lock scans all slots,
//! applies the *sum* of pending operations to `Main` with a single
//! hardware F&A, and writes each participant's return value (base +
//! prefix of earlier deltas in scan order) back into its slot. Threads
//! that fail to get the lock spin on their own slot.
//!
//! Compared with Aggregating Funnels this serializes all combining
//! through one lock (the paper's critique of single-point combining),
//! but it combines aggressively — a useful ablation between "hardware
//! F&A" and "Aggregating Funnels" in our extended benchmarks.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

use super::{delta_to_u64, BatchStats, FetchAddObject};
use crate::sync::{Backoff, CachePadded};

struct Slot {
    /// Request sequence: odd = pending request, even = response ready.
    seq: AtomicU64,
    delta: AtomicI64,
    resp: AtomicU64,
}

/// Flat-combining fetch-and-add object (`CombiningTree` name kept for
/// the module's role as the tree/lock-based combining baseline slot in
/// the benchmark matrix).
pub struct CombiningTree {
    main: CachePadded<AtomicU64>,
    lock: CachePadded<AtomicBool>,
    slots: Vec<CachePadded<Slot>>,
    main_faas: CachePadded<AtomicU64>,
    ops: CachePadded<AtomicU64>,
}

impl CombiningTree {
    pub fn new(max_threads: usize) -> Self {
        let slots = (0..max_threads.max(1))
            .map(|_| {
                CachePadded::new(Slot {
                    seq: AtomicU64::new(0),
                    delta: AtomicI64::new(0),
                    resp: AtomicU64::new(0),
                })
            })
            .collect();
        Self {
            main: CachePadded::new(AtomicU64::new(0)),
            lock: CachePadded::new(AtomicBool::new(false)),
            slots,
            main_faas: CachePadded::new(AtomicU64::new(0)),
            ops: CachePadded::new(AtomicU64::new(0)),
        }
    }

    fn try_lock(&self) -> bool {
        !self.lock.load(Ordering::Relaxed)
            && self.lock.compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed).is_ok()
    }

    /// Serve every pending announcement (including the caller's).
    fn combine(&self) {
        // Gather pending requests in slot order.
        let mut pending: Vec<(usize, u64, i64)> = Vec::with_capacity(self.slots.len());
        let mut total: i64 = 0;
        for (i, slot) in self.slots.iter().enumerate() {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq % 2 == 1 {
                let d = slot.delta.load(Ordering::Relaxed);
                pending.push((i, seq, d));
                total = total.wrapping_add(d);
            }
        }
        if pending.is_empty() {
            return;
        }
        let base = self.main.fetch_add(delta_to_u64(total), Ordering::AcqRel);
        self.main_faas.fetch_add(1, Ordering::Relaxed);
        self.ops.fetch_add(pending.len() as u64, Ordering::Relaxed);
        let mut prefix = base;
        for (i, seq, d) in pending {
            let slot = &self.slots[i];
            slot.resp.store(prefix, Ordering::Relaxed);
            slot.seq.store(seq + 1, Ordering::Release); // publish response
            prefix = prefix.wrapping_add(delta_to_u64(d));
        }
    }
}

impl FetchAddObject for CombiningTree {
    fn fetch_add(&self, tid: usize, delta: i64) -> u64 {
        if delta == 0 {
            return self.read(tid);
        }
        let slot = &self.slots[tid];
        // Publish the request: delta first, then flip seq to odd.
        slot.delta.store(delta, Ordering::Relaxed);
        let my_seq = slot.seq.load(Ordering::Relaxed) + 1;
        debug_assert_eq!(my_seq % 2, 1);
        slot.seq.store(my_seq, Ordering::Release);

        let mut backoff = Backoff::new();
        loop {
            // Response ready?
            if slot.seq.load(Ordering::Acquire) == my_seq + 1 {
                return slot.resp.load(Ordering::Relaxed);
            }
            // Otherwise try to become the combiner.
            if self.try_lock() {
                self.combine();
                self.lock.store(false, Ordering::Release);
                // Our own request is necessarily served now.
                debug_assert_eq!(slot.seq.load(Ordering::Acquire), my_seq + 1);
                return slot.resp.load(Ordering::Relaxed);
            }
            backoff.snooze();
        }
    }

    #[inline]
    fn read(&self, _tid: usize) -> u64 {
        self.main.load(Ordering::SeqCst)
    }

    #[inline]
    fn fetch_add_direct(&self, _tid: usize, delta: i64) -> u64 {
        self.main_faas.fetch_add(1, Ordering::Relaxed);
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.main.fetch_add(delta_to_u64(delta), Ordering::AcqRel)
    }

    #[inline]
    fn compare_and_swap(&self, _tid: usize, old: u64, new: u64) -> u64 {
        match self.main.compare_exchange(old, new, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(prev) => prev,
            Err(actual) => actual,
        }
    }

    #[inline]
    fn fetch_or(&self, _tid: usize, bits: u64) -> u64 {
        self.main.fetch_or(bits, Ordering::AcqRel)
    }

    fn max_threads(&self) -> usize {
        self.slots.len()
    }

    fn batch_stats(&self) -> BatchStats {
        BatchStats {
            main_faas: self.main_faas.load(Ordering::Relaxed),
            ops: self.ops.load(Ordering::Relaxed),
            ..BatchStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_semantics() {
        let f = CombiningTree::new(1);
        assert_eq!(f.fetch_add(0, 3), 0);
        assert_eq!(f.fetch_add(0, -1), 3);
        assert_eq!(f.read(0), 2);
    }

    #[test]
    fn concurrent_fetch_inc_dense() {
        let p = 8;
        let f = Arc::new(CombiningTree::new(p));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..2_000).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..(p as u64 * 2_000)).collect::<Vec<_>>());
    }

    #[test]
    fn mixed_signs_sum_conserved() {
        let p = 4;
        let f = Arc::new(CombiningTree::new(p));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for i in 0i64..5_000 {
                        f.fetch_add(tid, if i % 2 == 0 { -2 } else { 3 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let per: i64 = (0..5_000).map(|i| if i % 2 == 0 { -2 } else { 3 }).sum();
        assert_eq!(f.read(0) as i64, 4 * per);
    }

    #[test]
    fn combining_counts() {
        let f = CombiningTree::new(2);
        f.fetch_add(0, 1);
        f.fetch_add(1, 1);
        let s = f.batch_stats();
        assert_eq!(s.ops, 2);
        assert!(s.main_faas >= 1 && s.main_faas <= 2);
    }
}
