//! Adaptive funnel width: contention monitoring and width policies.
//!
//! The paper treats the number of Aggregators `m` as a static tuning
//! knob (§4.2 evaluates fixed widths; Algorithm 2 fixes `m = ⌊√p⌋`).
//! A production service, however, sees thread counts and contention
//! that vary at runtime: a fixed `m` is wasted memory at low load and
//! a hot spot at high load. This module supplies the two pieces an
//! elastic funnel ([`super::ElasticAggFunnel`]) needs to adapt:
//!
//! * [`ContentionMonitor`] — a lock-free, cache-padded, per-thread set
//!   of counters (batches applied, ops batched, single-op batches,
//!   CAS failures, overflow restarts). Writers touch only their own
//!   line with relaxed atomics, so the hot path pays one uncontended
//!   add; a controller thread reads a [`ContentionSnapshot`] at any
//!   time without stopping the world.
//! * [`WidthPolicy`] — the decision rule mapping a window of monitor
//!   deltas to a new active width: [`WidthPolicy::Fixed`] (the paper's
//!   static `m`), [`WidthPolicy::SqrtP`] (Algorithm 2's `⌊√p⌋` rule)
//!   and [`WidthPolicy::Aimd`] — additive-increase when batches run
//!   hot (high occupancy means each Aggregator is absorbing many
//!   concurrent ops), multiplicative-decrease when batches run
//!   near-empty (no combining is happening, so fewer Aggregators
//!   serve the same load with less per-op latency).
//!
//! The linearizability proof of §3.1 holds for *any* Aggregator
//! choice, so resizing the active set between epochs never threatens
//! correctness — only throughput. See `DESIGN.md` for how the elastic
//! funnel retires drained Aggregators safely.

use std::sync::atomic::{AtomicU64, Ordering};

use super::choose::sqrt_p_aggregators;
use super::BatchStats;
use crate::sync::CachePadded;

/// Per-thread monitor counters; one cache line per thread.
#[derive(Default)]
struct MonitorSlot {
    /// Batches this thread applied to `Main` as a delegate.
    batches: AtomicU64,
    /// Fetch&Add operations completed through the funnel.
    ops: AtomicU64,
    /// Batches that contained exactly one operation (no combining).
    single_op_batches: AtomicU64,
    /// Direct (`Fetch&AddDirect`) operations: each is its own F&A on
    /// `Main`, but they are kept out of the funnel counters so they
    /// cannot dilute the policy's batch-occupancy signals.
    direct_ops: AtomicU64,
    /// Failed `Compare&Swap` attempts observed on `Main`.
    cas_failures: AtomicU64,
    /// Operation restarts forced by Aggregator retirement.
    restarts: AtomicU64,
}

/// Lock-free contention statistics for an elastic funnel.
///
/// Each thread id owns one cache-padded slot; recording is a relaxed
/// `fetch_add` on the owner's line (never contended), and snapshots
/// are relaxed sums over all slots. Totals fold into the crate-wide
/// [`BatchStats`] so every consumer of the average-batch-size metric
/// sees the same numbers.
pub struct ContentionMonitor {
    slots: Vec<CachePadded<MonitorSlot>>,
}

impl ContentionMonitor {
    /// Monitor for thread ids `0..max_threads`.
    pub fn new(max_threads: usize) -> Self {
        Self {
            slots: (0..max_threads.max(1))
                .map(|_| CachePadded::new(MonitorSlot::default()))
                .collect(),
        }
    }

    #[inline]
    fn slot(&self, tid: usize) -> &MonitorSlot {
        &self.slots[tid]
    }

    /// One funnelled operation completed (delegate or not).
    #[inline]
    pub fn record_op(&self, tid: usize) {
        self.slot(tid).ops.fetch_add(1, Ordering::Relaxed);
    }

    /// A delegate applied one batch to `Main`. `single` marks a batch
    /// that contained only the delegate's own operation.
    #[inline]
    pub fn record_batch(&self, tid: usize, single: bool) {
        let s = self.slot(tid);
        s.batches.fetch_add(1, Ordering::Relaxed);
        if single {
            s.single_op_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A direct (`Fetch&AddDirect`) operation: its own F&A on `Main`,
    /// counted separately so the width policy only sees funnel
    /// traffic (a priority-heavy workload must not mask the funnel's
    /// grow/shrink signals).
    #[inline]
    pub fn record_direct(&self, tid: usize) {
        self.slot(tid).direct_ops.fetch_add(1, Ordering::Relaxed);
    }

    /// A `Compare&Swap` on `Main` witnessed a value other than `old`.
    #[inline]
    pub fn record_cas_failure(&self, tid: usize) {
        self.slot(tid).cas_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// An operation restarted because its Aggregator was retired.
    #[inline]
    pub fn record_restart(&self, tid: usize) {
        self.slot(tid).restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Relaxed sum over every thread's counters.
    pub fn snapshot(&self) -> ContentionSnapshot {
        let mut snap = ContentionSnapshot::default();
        for s in &self.slots {
            snap.batches += s.batches.load(Ordering::Relaxed);
            snap.batched_ops += s.ops.load(Ordering::Relaxed);
            snap.single_op_batches += s.single_op_batches.load(Ordering::Relaxed);
            snap.direct_ops += s.direct_ops.load(Ordering::Relaxed);
            snap.cas_failures += s.cas_failures.load(Ordering::Relaxed);
            snap.restarts += s.restarts.load(Ordering::Relaxed);
        }
        snap
    }

    /// Fold the totals into the crate-wide batch-statistics record.
    /// Direct ops count here (each is one F&A on `Main` that retired
    /// one op, matching the static funnel's accounting) even though
    /// the policy-facing ratios exclude them.
    pub fn fold_into(&self, stats: &mut BatchStats) {
        let snap = self.snapshot();
        stats.main_faas += snap.batches + snap.direct_ops;
        stats.ops += snap.batched_ops + snap.direct_ops;
        stats.single_op_batches += snap.single_op_batches;
        stats.cas_failures += snap.cas_failures;
    }
}

/// A point-in-time (or windowed, via [`ContentionSnapshot::delta`])
/// view of a [`ContentionMonitor`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ContentionSnapshot {
    /// Batches applied to `Main` by funnel delegates (directs excluded
    /// so they cannot dilute the occupancy ratios below).
    pub batches: u64,
    /// Funnelled operations those batches accomplished.
    pub batched_ops: u64,
    /// Batches containing exactly one operation.
    pub single_op_batches: u64,
    /// `Fetch&AddDirect` operations (one F&A on `Main` each).
    pub direct_ops: u64,
    /// Failed CAS attempts on `Main`.
    pub cas_failures: u64,
    /// Retirement-forced operation restarts.
    pub restarts: u64,
}

impl ContentionSnapshot {
    /// Counters accumulated since `earlier` (saturating).
    pub fn delta(&self, earlier: &ContentionSnapshot) -> ContentionSnapshot {
        ContentionSnapshot {
            batches: self.batches.saturating_sub(earlier.batches),
            batched_ops: self.batched_ops.saturating_sub(earlier.batched_ops),
            single_op_batches: self
                .single_op_batches
                .saturating_sub(earlier.single_op_batches),
            direct_ops: self.direct_ops.saturating_sub(earlier.direct_ops),
            cas_failures: self.cas_failures.saturating_sub(earlier.cas_failures),
            restarts: self.restarts.saturating_sub(earlier.restarts),
        }
    }

    /// Operations per F&A on `Main` (the paper's §4.1 metric); 0.0
    /// when the window saw no batches.
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_ops as f64 / self.batches as f64
        }
    }

    /// Fraction of batches that combined nothing; 0.0 when empty.
    pub fn single_fraction(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.single_op_batches as f64 / self.batches as f64
        }
    }
}

/// Tuning knobs for [`WidthPolicy::Aimd`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AimdParams {
    /// Additive-increase trigger: grow by one Aggregator when the
    /// window's average batch size reaches this occupancy.
    pub grow_batch: f64,
    /// Multiplicative-decrease trigger: halve the width when at least
    /// this fraction of the window's batches combined nothing.
    pub shrink_single_fraction: f64,
    /// Never shrink below this width.
    pub min_width: usize,
}

impl Default for AimdParams {
    fn default() -> Self {
        // Occupancy 4 means each Main F&A is retiring four ops — the
        // Aggregator lines are clearly the hot spot, so spread. A
        // window where most batches are singletons means combining is
        // not paying for the funnel detour — collapse quickly.
        Self { grow_batch: 4.0, shrink_single_fraction: 0.5, min_width: 1 }
    }
}

/// How an elastic funnel sizes its active Aggregator set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WidthPolicy {
    /// A constant width — the paper's static `m` (AGGFUNNEL-m).
    Fixed(usize),
    /// Algorithm 2's `m = ⌊√p⌋` rule, recomputed from the funnel's
    /// thread bound.
    SqrtP,
    /// Additive-increase / multiplicative-decrease driven by the
    /// contention window (see [`AimdParams`]).
    Aimd(AimdParams),
}

impl WidthPolicy {
    /// The width to start a funnel at, before any window has elapsed.
    pub fn initial_width(&self, p: usize, max_width: usize) -> usize {
        let w = match self {
            WidthPolicy::Fixed(m) => *m,
            WidthPolicy::SqrtP => sqrt_p_aggregators(p),
            // AIMD starts at the floor and earns its width from
            // observed contention, like a TCP slow-start without the
            // exponential phase.
            WidthPolicy::Aimd(a) => a.min_width,
        };
        w.clamp(1, max_width.max(1))
    }

    /// Decide the next active width given the current one and a
    /// window of contention counters.
    pub fn decide(
        &self,
        p: usize,
        current: usize,
        max_width: usize,
        window: &ContentionSnapshot,
    ) -> usize {
        let max_width = max_width.max(1);
        let target = match self {
            WidthPolicy::Fixed(m) => *m,
            WidthPolicy::SqrtP => sqrt_p_aggregators(p),
            WidthPolicy::Aimd(a) => {
                if window.batches == 0 {
                    // Quiet window: no evidence either way.
                    current
                } else if window.avg_batch() >= a.grow_batch {
                    current + 1
                } else if window.single_fraction() >= a.shrink_single_fraction {
                    (current / 2).max(a.min_width)
                } else {
                    current
                }
            }
        };
        target.clamp(1, max_width)
    }

    /// Parse a CLI/config spelling: `fixed:<m>` (or a bare integer),
    /// `sqrtp`, or `aimd`.
    pub fn parse(s: &str) -> Option<WidthPolicy> {
        let s = s.trim();
        if let Some(m) = s.strip_prefix("fixed:") {
            return m.trim().parse().ok().map(WidthPolicy::Fixed);
        }
        match s {
            "sqrtp" | "sqrt-p" | "sqrt_p" => Some(WidthPolicy::SqrtP),
            "aimd" => Some(WidthPolicy::Aimd(AimdParams::default())),
            _ => s.parse().ok().map(WidthPolicy::Fixed),
        }
    }

    /// Stable display name, used as a benchmark series label.
    pub fn label(&self) -> String {
        match self {
            WidthPolicy::Fixed(m) => format!("fixed-{m}"),
            WidthPolicy::SqrtP => "sqrtp".into(),
            WidthPolicy::Aimd(_) => "aimd".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monitor_counts_and_snapshots() {
        let m = ContentionMonitor::new(2);
        m.record_op(0);
        m.record_op(1);
        m.record_batch(0, false);
        m.record_batch(1, true);
        m.record_direct(0);
        m.record_cas_failure(1);
        m.record_restart(0);
        let s = m.snapshot();
        assert_eq!(s.batched_ops, 2, "directs stay out of the funnel ops");
        assert_eq!(s.batches, 2, "directs stay out of the batch count");
        assert_eq!(s.direct_ops, 1);
        assert_eq!(s.single_op_batches, 1);
        assert_eq!(s.cas_failures, 1);
        assert_eq!(s.restarts, 1);
    }

    #[test]
    fn direct_traffic_does_not_dilute_policy_ratios() {
        // A priority-heavy workload whose funnel batches are all
        // singletons must still trip the AIMD shrink signal.
        let m = ContentionMonitor::new(1);
        for _ in 0..1_000 {
            m.record_direct(0);
        }
        for _ in 0..10 {
            m.record_op(0);
            m.record_batch(0, true);
        }
        let s = m.snapshot();
        assert!((s.single_fraction() - 1.0).abs() < 1e-12);
        let aimd = WidthPolicy::Aimd(AimdParams::default());
        assert_eq!(aimd.decide(8, 6, 12, &s), 3, "shrink despite direct flood");
    }

    #[test]
    fn snapshot_delta_and_ratios() {
        let a = ContentionSnapshot { batches: 10, batched_ops: 40, single_op_batches: 2, ..Default::default() };
        let b = ContentionSnapshot { batches: 30, batched_ops: 60, single_op_batches: 17, ..Default::default() };
        let w = b.delta(&a);
        assert_eq!(w.batches, 20);
        assert_eq!(w.batched_ops, 20);
        assert!((w.avg_batch() - 1.0).abs() < 1e-12);
        assert!((w.single_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(ContentionSnapshot::default().avg_batch(), 0.0);
    }

    #[test]
    fn fold_into_batch_stats_includes_directs() {
        let m = ContentionMonitor::new(1);
        m.record_op(0);
        m.record_batch(0, true);
        m.record_direct(0);
        let mut stats = BatchStats::default();
        m.fold_into(&mut stats);
        assert_eq!(stats.ops, 2, "funnel op + direct op");
        assert_eq!(stats.main_faas, 2, "one batch + one direct F&A");
        assert_eq!(stats.single_op_batches, 1);
    }

    #[test]
    fn aimd_grows_on_high_occupancy() {
        let p = WidthPolicy::Aimd(AimdParams::default());
        let hot = ContentionSnapshot { batches: 100, batched_ops: 900, ..Default::default() };
        assert_eq!(p.decide(64, 4, 12, &hot), 5);
        // Capped at max_width.
        assert_eq!(p.decide(64, 12, 12, &hot), 12);
    }

    #[test]
    fn aimd_halves_on_near_empty_batches() {
        let p = WidthPolicy::Aimd(AimdParams::default());
        let cold = ContentionSnapshot {
            batches: 100,
            batched_ops: 110,
            single_op_batches: 95,
            ..Default::default()
        };
        assert_eq!(p.decide(64, 8, 12, &cold), 4);
        assert_eq!(p.decide(64, 1, 12, &cold), 1, "floor holds");
    }

    #[test]
    fn aimd_holds_on_quiet_or_balanced_windows() {
        let p = WidthPolicy::Aimd(AimdParams::default());
        assert_eq!(p.decide(64, 6, 12, &ContentionSnapshot::default()), 6);
        let balanced = ContentionSnapshot {
            batches: 100,
            batched_ops: 250, // avg 2.5: below grow, above near-empty
            single_op_batches: 10,
            ..Default::default()
        };
        assert_eq!(p.decide(64, 6, 12, &balanced), 6);
    }

    #[test]
    fn static_policies_ignore_the_window() {
        let w = ContentionSnapshot { batches: 1, batched_ops: 1000, ..Default::default() };
        assert_eq!(WidthPolicy::Fixed(6).decide(176, 2, 12, &w), 6);
        assert_eq!(WidthPolicy::SqrtP.decide(176, 2, 16, &w), 13);
        assert_eq!(WidthPolicy::Fixed(99).decide(176, 2, 12, &w), 12, "clamped");
    }

    #[test]
    fn initial_widths() {
        assert_eq!(WidthPolicy::Fixed(6).initial_width(176, 12), 6);
        assert_eq!(WidthPolicy::SqrtP.initial_width(176, 12), 12, "√176=13 clamps to 12");
        assert_eq!(WidthPolicy::Aimd(AimdParams::default()).initial_width(176, 12), 1);
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(WidthPolicy::parse("fixed:6"), Some(WidthPolicy::Fixed(6)));
        assert_eq!(WidthPolicy::parse("4"), Some(WidthPolicy::Fixed(4)));
        assert_eq!(WidthPolicy::parse("sqrtp"), Some(WidthPolicy::SqrtP));
        assert_eq!(
            WidthPolicy::parse("aimd"),
            Some(WidthPolicy::Aimd(AimdParams::default()))
        );
        assert_eq!(WidthPolicy::parse("nope"), None);
        assert_eq!(WidthPolicy::parse("fixed-6"), None);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(WidthPolicy::Fixed(6).label(), "fixed-6");
        assert_eq!(WidthPolicy::SqrtP.label(), "sqrtp");
        assert_eq!(WidthPolicy::Aimd(AimdParams::default()).label(), "aimd");
    }
}
