//! The "hardware F&A" baseline: a single cache-padded atomic word.
//!
//! Every operation is one hardware instruction on one location — the
//! configuration whose contention the paper's whole design exists to
//! dissipate. Fast at low thread counts, plateaus once the line
//! bounces between cores (paper §4.3: ~18 Mops/s on the 176-thread
//! primary testbed).

use std::sync::atomic::{AtomicU64, Ordering};

use super::{delta_to_u64, FetchAddObject};
use crate::sync::CachePadded;

/// A fetch-and-add object backed directly by one `AtomicU64`.
pub struct HardwareFaa {
    main: CachePadded<AtomicU64>,
    max_threads: usize,
}

impl HardwareFaa {
    pub fn new(max_threads: usize) -> Self {
        Self::with_initial(max_threads, 0)
    }

    pub fn with_initial(max_threads: usize, initial: u64) -> Self {
        Self { main: CachePadded::new(AtomicU64::new(initial)), max_threads }
    }
}

impl FetchAddObject for HardwareFaa {
    #[inline]
    fn fetch_add(&self, _tid: usize, delta: i64) -> u64 {
        self.main.fetch_add(delta_to_u64(delta), Ordering::AcqRel)
    }

    #[inline]
    fn read(&self, _tid: usize) -> u64 {
        self.main.load(Ordering::SeqCst)
    }

    #[inline]
    fn compare_and_swap(&self, _tid: usize, old: u64, new: u64) -> u64 {
        match self.main.compare_exchange(old, new, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(prev) => prev,
            Err(actual) => actual,
        }
    }

    #[inline]
    fn fetch_or(&self, _tid: usize, bits: u64) -> u64 {
        self.main.fetch_or(bits, Ordering::AcqRel)
    }

    fn max_threads(&self) -> usize {
        self.max_threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_semantics() {
        let f = HardwareFaa::new(1);
        assert_eq!(f.fetch_add(0, 5), 0);
        assert_eq!(f.fetch_add(0, -2), 5);
        assert_eq!(f.read(0), 3);
        assert_eq!(f.compare_and_swap(0, 3, 100), 3);
        assert_eq!(f.read(0), 100);
        assert_eq!(f.compare_and_swap(0, 3, 7), 100, "failed CAS returns witness");
        assert_eq!(f.fetch_or(0, 0b11), 100);
        assert_eq!(f.read(0), 100 | 0b11);
    }

    #[test]
    fn wraps_modulo_2_64() {
        let f = HardwareFaa::with_initial(1, u64::MAX);
        assert_eq!(f.fetch_add(0, 1), u64::MAX);
        assert_eq!(f.read(0), 0);
        assert_eq!(f.fetch_add(0, -1), 0);
        assert_eq!(f.read(0), u64::MAX);
    }

    #[test]
    fn concurrent_sum_conserved() {
        let f = Arc::new(HardwareFaa::new(8));
        let per_thread = 10_000i64;
        let handles: Vec<_> = (0..8)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        f.fetch_add(tid, if i % 3 == 0 { -1 } else { 2 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // per thread: ceil(10000/3) ops of -1 and the rest +2
        let minus = (0..per_thread).filter(|i| i % 3 == 0).count() as i64;
        let expected = 8 * (-minus + 2 * (per_thread - minus));
        assert_eq!(f.read(0), expected as u64);
    }

    #[test]
    fn distinct_results_under_concurrency() {
        let f = Arc::new(HardwareFaa::new(4));
        let handles: Vec<_> = (0..4)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || (0..1000).map(|_| f.fetch_add(tid, 1)).collect::<Vec<_>>())
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4000, "fetch&inc results must be distinct");
    }
}
