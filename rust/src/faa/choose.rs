//! `ChooseAggregator` policies (paper §3.1, Algorithm 2, §4.2, §4.4).
//!
//! The proof of linearizability holds for *any* choice of Aggregator,
//! so the policy is a pure tuning knob. This enum covers the two
//! *per-operation* selection rules the crate implements:
//!
//! * [`Choose::StaticEven`] — each thread always uses Aggregator
//!   `tid % m`, spreading threads so per-Aggregator load differs by at
//!   most one (the paper's default for all main experiments);
//! * [`Choose::Random`] — uniformly random Aggregator per operation
//!   (mentioned in the paper as an alternative).
//!
//! Two schemes the paper also evaluates are **not** `Choose` variants,
//! because they size or partition the funnel rather than pick within
//! it — find them where they actually live:
//!
//! * Algorithm 2's **√p grouping** fixes `m = ⌊√p⌋` and then uses the
//!   static assignment above; [`sqrt_p_aggregators`] computes that `m`
//!   for [`super::AggFunnelConfig::with_aggregators`], and
//!   [`super::WidthPolicy::SqrtP`] applies the same rule to an elastic
//!   funnel.
//! * the **asymmetric (m, d)** scheme of §4.4, where `d` high-priority
//!   threads bypass the funnel entirely, is
//!   [`super::AggFunnelConfig::with_direct_threads`] (routing to
//!   `fetch_add_direct`), not a selection policy.
//!
//! Elastic funnels ([`super::ElasticAggFunnel`]) apply a `Choose` over
//! their *active prefix* only: `m` here is whatever width the
//! [`super::WidthPolicy`] has currently granted, so the same two
//! variants cover the adaptive case unchanged.

/// Aggregator selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Choose {
    /// Thread `tid` always uses Aggregator `tid % m` (static,
    /// symmetric, even). The paper's default for AGGFUNNEL-m.
    StaticEven,
    /// Uniformly random Aggregator for every operation.
    Random,
}

impl Choose {
    /// Pick an Aggregator index in `0..m`.
    ///
    /// `rand` supplies entropy only for `Random` (it is not consulted
    /// for the static policy, so static callers may pass a dummy).
    #[inline]
    pub fn pick(self, tid: usize, m: usize, rand: impl FnOnce() -> u64) -> usize {
        debug_assert!(m > 0);
        match self {
            Choose::StaticEven => tid % m,
            Choose::Random => (rand() % m as u64) as usize,
        }
    }
}

/// The paper's Algorithm 2: `m = ⌊√p⌋` Aggregators per sign with √p
/// threads per group. Returns the `m` to build an [`super::AggFunnel`]
/// with to reproduce that configuration; the elastic counterpart is
/// [`super::WidthPolicy::SqrtP`], which re-applies this rule whenever
/// the controller polls.
pub fn sqrt_p_aggregators(p: usize) -> usize {
    ((p as f64).sqrt().floor() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_even_is_balanced() {
        let m = 6;
        let p = 176;
        let mut load = vec![0usize; m];
        for tid in 0..p {
            load[Choose::StaticEven.pick(tid, m, || unreachable!())] += 1;
        }
        let min = *load.iter().min().unwrap();
        let max = *load.iter().max().unwrap();
        assert!(max - min <= 1, "load {load:?} not balanced");
    }

    #[test]
    fn static_even_is_stable_per_thread() {
        let a = Choose::StaticEven.pick(13, 6, || unreachable!());
        for _ in 0..10 {
            assert_eq!(Choose::StaticEven.pick(13, 6, || unreachable!()), a);
        }
    }

    #[test]
    fn random_in_range_and_uses_entropy() {
        let mut i = 0u64;
        for _ in 0..100 {
            let v = Choose::Random.pick(0, 7, || {
                i += 13;
                i
            });
            assert!(v < 7);
        }
    }

    #[test]
    fn sqrt_p_values() {
        assert_eq!(sqrt_p_aggregators(1), 1);
        assert_eq!(sqrt_p_aggregators(4), 2);
        assert_eq!(sqrt_p_aggregators(176), 13);
        assert_eq!(sqrt_p_aggregators(0), 1);
    }
}
