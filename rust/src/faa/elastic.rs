//! Elastic Aggregating Funnels: Algorithm 1 with a runtime-resizable
//! active Aggregator set.
//!
//! [`ElasticAggFunnel`] keeps `2 · max_width` Aggregator slots (the
//! capacity) but routes operations only over the *active prefix*
//! `0..active` of each sign's slots. A controller — the service's
//! resize thread, a benchmark harness, or any caller of
//! [`ElasticAggFunnel::poll_policy`] — moves the active width between
//! epochs, driven by a [`WidthPolicy`] over the funnel's
//! [`ContentionMonitor`] window.
//!
//! # How resizing stays linearizable
//!
//! The §3.1 proof holds for *any* `ChooseAggregator`, so changing the
//! choice set over time cannot break linearizability; the only new
//! obligation is that no operation is stranded on a deactivated
//! Aggregator. Resizing therefore reuses the paper's own overflow
//! machinery (the cyan code) instead of inventing a second protocol:
//!
//! * **Grow** is trivial — the slots already exist, each holding a
//!   fresh Aggregator; raising `active` just lets `Choose` pick them.
//! * **Shrink** only lowers `active`. Operations already registered on
//!   a deactivated Aggregator finish normally; the *next delegate* on
//!   it observes `index >= active` and retires it exactly as if it had
//!   crossed `threshold` — replace the slot, publish `final`, send the
//!   drained Aggregator to [`crate::ebr`]. Stragglers that registered
//!   after the delegate's closing read observe `final`, restart, and
//!   re-run `Choose` over the *current* active prefix (unlike the
//!   static funnel, a restart here re-chooses). An idle deactivated
//!   Aggregator holds no operations and is simply reclaimed on drop —
//!   retirement is lazy, bounded by one batch per deactivated slot.
//!
//! The delegate cannot count the operations in its batch (it only sees
//! the magnitude sum), but it *can* detect a batch that combined
//! nothing: the sum equals its own magnitude iff no one else joined
//! (every magnitude is ≥ 1). That single bit per batch is what the
//! AIMD policy's multiplicative-decrease feeds on.

use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};

use super::aggfunnel::{
    await_batch, free_aggregator, non_delegate_result, Aggregator, AtomicMain, Batch, MainCell,
};
use super::choose::Choose;
use super::width::{ContentionMonitor, ContentionSnapshot, WidthPolicy};
use super::{BatchStats, FetchAddObject};
use crate::ebr;
use crate::sync::{CachePadded, CasCtl, RetryPolicy, SpinLock};
use crate::util::rng::Rng;

/// Construction parameters for an [`ElasticAggFunnel`].
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Maximum number of threads (`p`); thread ids are `0..p`.
    pub max_threads: usize,
    /// Aggregator slots per sign (the elastic capacity). The active
    /// width never exceeds this.
    pub max_width: usize,
    /// Policy that sizes the active prefix (also determines the
    /// initial width).
    pub policy: WidthPolicy,
    /// Aggregator retirement threshold (paper default 2⁶³).
    pub threshold: u64,
    /// Aggregator selection policy over the active prefix.
    pub choose: Choose,
    /// Seed for the per-thread RNGs used by `Choose::Random`.
    pub seed: u64,
    /// Recording mode for the linearizability verifier: keeps every
    /// Batch chain and retired Aggregator alive so
    /// [`ElasticAggFunnel::extract_history`] can reconstruct the run.
    pub record: bool,
    /// Retry policy pacing the restart loop (overflow *and*
    /// width-epoch deactivation drains go through it). Swappable at
    /// runtime through [`FetchAddObject::set_cas_policy`].
    pub cas_policy: RetryPolicy,
}

impl ElasticConfig {
    /// Defaults: capacity 12 per sign, AIMD policy, threshold 2⁶³,
    /// static-even choice.
    pub fn new(max_threads: usize) -> Self {
        Self {
            max_threads: max_threads.max(1),
            max_width: 12,
            policy: WidthPolicy::Aimd(super::width::AimdParams::default()),
            threshold: 1 << 63,
            choose: Choose::StaticEven,
            seed: 0xE1A5_71C5,
            record: false,
            cas_policy: RetryPolicy::default(),
        }
    }

    pub fn with_max_width(mut self, w: usize) -> Self {
        self.max_width = w.max(1);
        self
    }

    pub fn with_policy(mut self, p: WidthPolicy) -> Self {
        self.policy = p;
        self
    }

    pub fn with_threshold(mut self, t: u64) -> Self {
        self.threshold = t;
        self
    }

    pub fn with_choose(mut self, c: Choose) -> Self {
        self.choose = c;
        self
    }

    pub fn with_cas_policy(mut self, p: RetryPolicy) -> Self {
        self.cas_policy = p;
        self
    }

    /// Enable history recording (verifier mode). Forces an effectively
    /// infinite overflow threshold so batch chains stay walkable;
    /// resize-driven retirement still happens and is logged.
    pub fn with_recording(mut self) -> Self {
        self.record = true;
        self.threshold = u64::MAX;
        self
    }
}

/// One recorded funnelled operation (verifier mode). Unlike the static
/// funnel's record, the Aggregator is identified by pointer rather
/// than slot index: a resizing run can retire several Aggregator
/// *generations* through the same slot, and each generation's `value`
/// sequence restarts at zero.
#[derive(Clone, Copy, Debug)]
struct ElasticOpRecord {
    /// The Aggregator this operation's batch lives on.
    agg: *mut Aggregator,
    /// Result of the op's F&A on the Aggregator's `value`.
    a_before: u64,
    /// The operation's |delta|.
    magnitude: u64,
    /// The value the operation returned to its caller.
    result: u64,
}

/// A retired Aggregator kept alive for history extraction.
struct RetiredAgg {
    ptr: *mut Aggregator,
    /// Slot index at retirement (`>= max_width` means negative sign).
    index: usize,
}

// Safety: raw pointers in records are only dereferenced after every
// worker thread has quiesced (extract_history contract), and the
// pointees are never freed in recording mode before drop.
unsafe impl Send for RetiredAgg {}

/// Per-thread scratch state.
struct ElasticScratch {
    rng: Rng,
    /// Recorded operations (verifier mode only).
    records: Vec<ElasticOpRecord>,
}

/// Controller-side bookkeeping for [`ElasticAggFunnel::poll_policy`].
#[derive(Default)]
struct ControllerState {
    last: ContentionSnapshot,
}

/// Aggregating Funnels with an adaptively sized Aggregator set.
///
/// Implements [`FetchAddObject`] exactly like [`super::AggFunnel`]
/// (same batching protocol, same RMWability, same EBR reclamation) and
/// adds [`resize`](Self::resize) / [`poll_policy`](Self::poll_policy)
/// for width control plus a [`ContentionMonitor`] for observability.
pub struct ElasticAggFunnel {
    main: AtomicMain,
    /// `agg[0..max_width)` positive, `agg[max_width..2·max_width)`
    /// negative. Slot offsets use `max_width` (capacity), never the
    /// active width, so slots are stable across resizes.
    agg: Vec<CachePadded<AtomicPtr<Aggregator>>>,
    /// Active Aggregators per sign; picks route over `0..active`.
    active: CachePadded<AtomicUsize>,
    resizes: AtomicU64,
    cfg: ElasticConfig,
    /// Paces the restart loop (overflow + deactivation drains).
    cas: CasCtl,
    monitor: ContentionMonitor,
    ebr: ebr::Domain,
    scratch: Vec<CachePadded<std::cell::UnsafeCell<ElasticScratch>>>,
    /// Aggregators retired while recording (verifier mode only).
    retired_log: SpinLock<Vec<RetiredAgg>>,
    controller: SpinLock<ControllerState>,
}

unsafe impl Send for ElasticAggFunnel {}
unsafe impl Sync for ElasticAggFunnel {}

impl ElasticAggFunnel {
    /// Build with defaults (AIMD policy, capacity 12) for `p` threads.
    pub fn new(max_threads: usize) -> Self {
        Self::with_config(ElasticConfig::new(max_threads))
    }

    /// Build with an explicit configuration.
    pub fn with_config(cfg: ElasticConfig) -> Self {
        let m2 = cfg.max_width * 2;
        let agg = (0..m2)
            .map(|_| CachePadded::new(AtomicPtr::new(Box::into_raw(Aggregator::boxed()))))
            .collect();
        let mut seed_rng = Rng::new(cfg.seed);
        let scratch = (0..cfg.max_threads)
            .map(|t| {
                CachePadded::new(std::cell::UnsafeCell::new(ElasticScratch {
                    rng: seed_rng.fork(t as u64),
                    records: Vec::new(),
                }))
            })
            .collect();
        let initial = cfg.policy.initial_width(cfg.max_threads, cfg.max_width);
        let ebr = ebr::Domain::new(cfg.max_threads);
        let monitor = ContentionMonitor::new(cfg.max_threads);
        Self {
            main: AtomicMain::new(0),
            agg,
            active: CachePadded::new(AtomicUsize::new(initial)),
            resizes: AtomicU64::new(0),
            cas: CasCtl::new(cfg.cas_policy),
            cfg,
            monitor,
            ebr,
            scratch,
            retired_log: SpinLock::new(Vec::new()),
            controller: SpinLock::new(ControllerState::default()),
        }
    }

    pub fn config(&self) -> &ElasticConfig {
        &self.cfg
    }

    /// The current active width per sign.
    pub fn active_width(&self) -> usize {
        self.active.load(Ordering::Acquire)
    }

    /// The fixed slot capacity per sign.
    pub fn max_width(&self) -> usize {
        self.cfg.max_width
    }

    /// Number of resizes applied so far.
    pub fn resizes(&self) -> u64 {
        self.resizes.load(Ordering::Relaxed)
    }

    /// The funnel's contention monitor (live counters).
    pub fn monitor(&self) -> &ContentionMonitor {
        &self.monitor
    }

    /// Set the active width (clamped to `1..=max_width`); returns the
    /// previous width. Safe to call from any thread at any time —
    /// in-flight operations on deactivated Aggregators drain through
    /// the overflow protocol (see the module docs).
    pub fn resize(&self, width: usize) -> usize {
        let width = width.clamp(1, self.cfg.max_width);
        let prev = self.active.swap(width, Ordering::AcqRel);
        if prev != width {
            self.resizes.fetch_add(1, Ordering::Relaxed);
        }
        prev
    }

    /// Apply `policy` to the contention window accumulated since the
    /// previous poll and resize if it says so; returns the (possibly
    /// new) active width. Intended for a single periodic controller —
    /// concurrent pollers serialize on an internal spinlock.
    pub fn poll_policy(&self, policy: &WidthPolicy) -> usize {
        let window = {
            // Snapshot under the lock: if a concurrent poller could
            // interleave between snapshot and store, an older snapshot
            // might overwrite a newer `last` and the next window would
            // double-count the gap.
            let mut ctl = self.controller.lock();
            let snap = self.monitor.snapshot();
            let w = snap.delta(&ctl.last);
            ctl.last = snap;
            w
        };
        let current = self.active_width();
        let target =
            policy.decide(self.cfg.max_threads, current, self.cfg.max_width, &window);
        if target != current {
            self.resize(target);
        }
        target
    }

    #[inline]
    fn scratch(&self, tid: usize) -> &mut ElasticScratch {
        // Safety: `tid` is owned by exactly one OS thread (trait contract).
        unsafe { &mut *self.scratch[tid].get() }
    }

    /// Slot index for in-sign Aggregator `g`.
    #[inline]
    fn slot_index(&self, g: usize, positive: bool) -> usize {
        if positive {
            g
        } else {
            self.cfg.max_width + g
        }
    }

    /// The funnelled Fetch&Add path. Identical to the static funnel's
    /// lines 20–37 except that every (re)start re-runs `Choose` over
    /// the current active prefix.
    fn fetch_add_funnel(&self, tid: usize, delta: i64) -> u64 {
        let positive = delta > 0;
        let magnitude = delta.unsigned_abs();
        let guard = self.ebr.pin(tid);
        let mut retry = self.cas.retry(tid as u64);

        loop {
            // Re-read the active width on every attempt so restarts
            // route onto the post-resize prefix.
            let width = self.active.load(Ordering::Acquire).max(1);
            let g = {
                let scratch = self.scratch(tid);
                self.cfg.choose.pick(tid, width, || scratch.rng.next_u64())
            };
            let index = self.slot_index(g, positive);
            let slot = &self.agg[index];

            // Line 21: a ← Agg[index].
            let a_ptr = slot.load(Ordering::Acquire);
            debug_assert!(!a_ptr.is_null());
            let a = unsafe { &*a_ptr };

            // Line 22: register in a batch with a single F&A.
            let a_before = a.value.fetch_add(magnitude, Ordering::AcqRel);

            // Lines 23–24 (shared with the static funnel).
            let last_ptr = await_batch(a, a_before);
            if last_ptr.is_null() {
                // Aggregator was retired (overflow or deactivation);
                // restart with the full delta, re-choosing the slot.
                // Pace the retry: restarts cluster exactly when a
                // retirement storm or a width-epoch drain is underway.
                self.monitor.record_restart(tid);
                retry.on_fail();
                continue;
            }
            let batch = unsafe { &*last_ptr };
            retry.on_success();

            let result = if batch.after == a_before {
                // Lines 26–33: I am the delegate of the next batch.
                self.run_delegate(tid, index, a_ptr, last_ptr, a_before, magnitude, positive)
            } else {
                // Lines 34–37: my batch is already linked; find it and
                // derive my return value (shared with the static funnel).
                non_delegate_result(batch, a_before, positive)
            };
            self.monitor.record_op(tid);
            if self.cfg.record {
                self.scratch(tid).records.push(ElasticOpRecord {
                    agg: a_ptr,
                    a_before,
                    magnitude,
                    result,
                });
            }
            drop(guard);
            return result;
        }
    }

    /// Delegate path (lines 26–33) plus the elastic retirement rule:
    /// an Aggregator is retired when it crosses `threshold` *or* when
    /// its slot has been deactivated by a shrink.
    #[allow(clippy::too_many_arguments)]
    fn run_delegate(
        &self,
        tid: usize,
        index: usize,
        a_ptr: *mut Aggregator,
        last_ptr: *mut Batch,
        a_before: u64,
        magnitude: u64,
        positive: bool,
    ) -> u64 {
        let a = unsafe { &*a_ptr };

        // Line 27: read the Aggregator's value — this closes the batch.
        let a_after = a.value.load(Ordering::Acquire);
        debug_assert!(a_after > a_before);
        let sum = a_after.wrapping_sub(a_before);

        // Line 28: apply the whole batch to Main with one F&A.
        let signed_sum = if positive { sum as i64 } else { (sum as i64).wrapping_neg() };
        let main_before = self.main.apply_add(tid, signed_sum);

        // Lines 29–31, extended: retire on overflow or deactivation.
        // Order is load-bearing: replace in Agg first, then set
        // `final`, so restarts always find a fresh Aggregator.
        let g = if index >= self.cfg.max_width { index - self.cfg.max_width } else { index };
        let deactivated = g >= self.active.load(Ordering::Acquire);
        let retired = a_after >= self.cfg.threshold || deactivated;
        if retired {
            let fresh = Box::into_raw(Aggregator::boxed());
            self.agg[index].store(fresh, Ordering::Release);
            a.tail.final_value.store(a_after, Ordering::Release);
        }

        // Line 32: publish the Batch record; waiters exit their loops.
        let new_batch = Box::into_raw(Box::new(Batch {
            before: a_before,
            after: a_after,
            main_before,
            previous: last_ptr,
        }));
        a.tail.last.store(new_batch, Ordering::Release);

        // §3.1.2 reclamation, as in the static funnel. In recording
        // mode the chain is kept alive (and retired Aggregators are
        // logged) for `extract_history`.
        if !self.cfg.record {
            self.ebr.retire_box(tid, unsafe { Box::from_raw(last_ptr) });
            if retired {
                self.ebr.retire_box(tid, unsafe { Box::from_raw(a_ptr) });
            }
        } else if retired {
            self.retired_log.lock().push(RetiredAgg { ptr: a_ptr, index });
        }

        // All magnitudes are ≥ 1, so the batch combined nothing iff
        // its sum is exactly the delegate's own magnitude.
        self.monitor.record_batch(tid, sum == magnitude);
        main_before // line 33
    }

    /// Reconstruct the full batch history of a recording-mode run,
    /// including every retired Aggregator generation.
    ///
    /// Must be called after all worker threads (and the resize
    /// controller) have finished. Returns the history in oracle layout
    /// plus, aligned with it, the value each operation actually
    /// returned — ready for [`crate::verify::verify_history_against`].
    /// Panics if the funnel was not built with
    /// [`ElasticConfig::with_recording`], and asserts Invariant 3.1
    /// per Aggregator while walking.
    pub fn extract_history(&self) -> (crate::runtime::BatchHistory, Vec<u64>) {
        assert!(self.cfg.record, "extract_history requires recording mode");
        // Every Aggregator generation that ever existed: retired ones
        // (in retirement order) then the ones still in the slots.
        let mut generations: Vec<(*mut Aggregator, usize)> = self
            .retired_log
            .lock()
            .iter()
            .map(|r| (r.ptr, r.index))
            .collect();
        for (index, slot) in self.agg.iter().enumerate() {
            generations.push((slot.load(Ordering::Acquire), index));
        }

        // Bucket op records by Aggregator pointer (recording mode never
        // frees, so pointers are unique generation keys).
        let mut per_agg: std::collections::HashMap<*mut Aggregator, Vec<ElasticOpRecord>> =
            std::collections::HashMap::new();
        for s in &self.scratch {
            let s = unsafe { &*s.get() };
            for r in &s.records {
                per_agg.entry(r.agg).or_default().push(*r);
            }
        }

        let mut history = crate::runtime::BatchHistory::default();
        let mut recorded = Vec::new();
        for (a_ptr, index) in generations {
            let Some(mut ops) = per_agg.remove(&a_ptr) else { continue };
            ops.sort_by_key(|r| r.a_before);
            let sign: i32 = if index < self.cfg.max_width { 1 } else { -1 };
            // Collect the chain oldest-first.
            let a = unsafe { &*a_ptr };
            let mut chain = Vec::new();
            let mut b = a.tail.last.load(Ordering::Acquire);
            while !b.is_null() {
                chain.push(unsafe { &*b });
                b = unsafe { (*b).previous };
            }
            chain.reverse();
            for w in chain.windows(2) {
                assert_eq!(w[0].after, w[1].before, "Invariant 3.1: contiguity violated");
            }
            let mut op_iter = ops.iter().peekable();
            for batch in chain.iter().skip(1) {
                // skip the sentinel (before == after == 0)
                assert!(batch.after > batch.before, "Invariant 3.1: empty batch");
                let mut deltas = Vec::new();
                let mut cursor = batch.before;
                while let Some(r) = op_iter.peek() {
                    if r.a_before >= batch.after {
                        break;
                    }
                    assert_eq!(r.a_before, cursor, "ops within a batch must tile it exactly");
                    deltas.push(r.magnitude);
                    recorded.push(r.result);
                    cursor = cursor.wrapping_add(r.magnitude);
                    op_iter.next();
                }
                assert_eq!(cursor, batch.after, "batch sum mismatch (Invariant 3.1)");
                history.push_batch(batch.main_before, sign, &deltas);
            }
            assert!(op_iter.next().is_none(), "op not covered by any batch");
        }
        assert!(per_agg.is_empty(), "op recorded against an unknown Aggregator");
        (history, recorded)
    }

    /// Reclamation counters summed over threads: `(retired, freed)`.
    pub fn debug_ebr_stats(&self) -> (u64, u64) {
        let mut retired = 0;
        let mut freed = 0;
        for tid in 0..self.cfg.max_threads {
            let (r, f) = self.ebr.stats(tid);
            retired += r;
            freed += f;
        }
        (retired, freed)
    }
}

impl FetchAddObject for ElasticAggFunnel {
    fn fetch_add(&self, tid: usize, delta: i64) -> u64 {
        if delta == 0 {
            return self.read(tid); // line 19: Fetch&Add(0) is a Read
        }
        self.fetch_add_funnel(tid, delta)
    }

    #[inline]
    fn read(&self, tid: usize) -> u64 {
        self.main.load(tid)
    }

    #[inline]
    fn fetch_add_direct(&self, tid: usize, delta: i64) -> u64 {
        self.monitor.record_direct(tid);
        self.main.apply_add(tid, delta)
    }

    #[inline]
    fn compare_and_swap(&self, tid: usize, old: u64, new: u64) -> u64 {
        let witnessed = self.main.cas(tid, old, new);
        if witnessed != old {
            self.monitor.record_cas_failure(tid);
        }
        witnessed
    }

    #[inline]
    fn fetch_or(&self, tid: usize, bits: u64) -> u64 {
        self.main.or(tid, bits)
    }

    fn max_threads(&self) -> usize {
        self.cfg.max_threads
    }

    fn batch_stats(&self) -> BatchStats {
        let mut stats = BatchStats::default();
        self.monitor.fold_into(&mut stats);
        stats
    }

    fn set_cas_policy(&self, policy: RetryPolicy) {
        self.cas.set(policy);
    }

    fn cas_policy(&self) -> Option<RetryPolicy> {
        Some(self.cas.get())
    }
}

impl Drop for ElasticAggFunnel {
    fn drop(&mut self) {
        for r in self.retired_log.lock().drain(..) {
            // Only populated in recording mode (otherwise EBR owns
            // retired Aggregators); chains are kept alive there, so
            // free them along with the Aggregator.
            free_aggregator(r.ptr, true);
        }
        for slot in &self.agg {
            free_aggregator(slot.load(Ordering::Relaxed), self.cfg.record);
        }
        // Retired Aggregators/Batches from non-recording runs are
        // freed by the EBR domain drop.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_matches_hardware_semantics() {
        let f = ElasticAggFunnel::new(1);
        assert_eq!(f.fetch_add(0, 5), 0);
        assert_eq!(f.fetch_add(0, 3), 5);
        assert_eq!(f.fetch_add(0, -2), 8);
        assert_eq!(f.read(0), 6);
        assert_eq!(f.fetch_add(0, 0), 6, "Fetch&Add(0) is a Read");
    }

    #[test]
    fn rmw_and_direct_hit_main() {
        let f = ElasticAggFunnel::new(2);
        f.fetch_add(0, 10);
        assert_eq!(f.compare_and_swap(0, 10, 99), 10);
        assert_eq!(f.compare_and_swap(1, 5, 7), 99, "failed CAS witnesses");
        assert_eq!(f.fetch_or(1, 0b100), 99);
        assert_eq!(f.fetch_add_direct(0, 1), 99 | 0b100);
        let stats = f.batch_stats();
        assert_eq!(stats.cas_failures, 1);
        assert!(stats.ops >= 2);
    }

    #[test]
    fn resize_clamps_and_counts() {
        let f = ElasticAggFunnel::with_config(
            ElasticConfig::new(4).with_max_width(8).with_policy(WidthPolicy::Fixed(3)),
        );
        assert_eq!(f.active_width(), 3);
        assert_eq!(f.resize(5), 3);
        assert_eq!(f.active_width(), 5);
        assert_eq!(f.resize(100), 5);
        assert_eq!(f.active_width(), 8, "clamped to capacity");
        f.resize(0);
        assert_eq!(f.active_width(), 1, "clamped to 1");
        assert_eq!(f.resizes(), 3);
        f.resize(1);
        assert_eq!(f.resizes(), 3, "no-op resize not counted");
    }

    #[test]
    fn poll_policy_applies_aimd() {
        let f = ElasticAggFunnel::with_config(
            ElasticConfig::new(8).with_max_width(8).with_policy(WidthPolicy::Fixed(2)),
        );
        // Manufacture a hot window: many ops per batch.
        for _ in 0..64 {
            f.monitor().record_op(0);
        }
        for _ in 0..4 {
            f.monitor().record_batch(0, false);
        }
        let aimd = WidthPolicy::Aimd(super::super::width::AimdParams::default());
        assert_eq!(f.poll_policy(&aimd), 3, "avg batch 16 grows 2 -> 3");
        // Second poll sees an empty window: hold.
        assert_eq!(f.poll_policy(&aimd), 3);
    }

    #[test]
    fn dense_tickets_while_resizing() {
        let p = 6;
        let per_thread = 3_000usize;
        let f = Arc::new(ElasticAggFunnel::with_config(
            ElasticConfig::new(p).with_max_width(6).with_policy(WidthPolicy::Fixed(4)),
        ));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let controller = {
            let f = Arc::clone(&f);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut w = 1usize;
                while !stop.load(Ordering::Relaxed) {
                    f.resize(w);
                    w = w % 6 + 1;
                    std::thread::yield_now();
                }
            })
        };
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..per_thread).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        stop.store(true, Ordering::Relaxed);
        controller.join().unwrap();
        all.sort_unstable();
        let n = p * per_thread;
        assert_eq!(all, (0..n as u64).collect::<Vec<_>>(), "lost or duplicated a ticket");
    }

    #[test]
    fn shrink_under_load_with_tiny_threshold() {
        // Overflow retirement and deactivation retirement interleave.
        let p = 4;
        let per_thread = 2_000usize;
        let f = Arc::new(ElasticAggFunnel::with_config(
            ElasticConfig::new(p)
                .with_max_width(4)
                .with_policy(WidthPolicy::Fixed(4))
                .with_threshold(64),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    let mut out = Vec::with_capacity(per_thread);
                    for i in 0..per_thread {
                        if tid == 0 && i == per_thread / 2 {
                            f.resize(1);
                        }
                        out.push(f.fetch_add(tid, 1));
                    }
                    out
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let n = p * per_thread;
        assert_eq!(all, (0..n as u64).collect::<Vec<_>>());
        let (retired, _freed) = f.debug_ebr_stats();
        assert!(retired > 0, "batches/aggregators must flow through EBR");
    }

    #[test]
    fn width_epoch_drain_correct_under_every_retry_policy() {
        // Deactivation-driven restarts are the loop the retry policies
        // pace here; shrink mid-run under each policy and demand a
        // dense ticket range.
        for policy in RetryPolicy::ALL {
            let p = 4;
            let per_thread = 800usize;
            let f = Arc::new(ElasticAggFunnel::with_config(
                ElasticConfig::new(p)
                    .with_max_width(4)
                    .with_policy(WidthPolicy::Fixed(4))
                    .with_threshold(64)
                    .with_cas_policy(policy),
            ));
            assert_eq!(f.cas_policy(), Some(policy));
            let handles: Vec<_> = (0..p)
                .map(|tid| {
                    let f = Arc::clone(&f);
                    std::thread::spawn(move || {
                        let mut out = Vec::with_capacity(per_thread);
                        for i in 0..per_thread {
                            if tid == 0 && i % 200 == 0 {
                                f.resize(1 + (i / 200) % 4);
                            }
                            out.push(f.fetch_add(tid, 1));
                        }
                        out
                    })
                })
                .collect();
            let mut all: Vec<u64> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            all.sort_unstable();
            let n = (p * per_thread) as u64;
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "policy {policy:?}");
        }
    }

    #[test]
    fn recorded_history_replays_with_resizes() {
        let p = 4;
        let f = Arc::new(ElasticAggFunnel::with_config(
            ElasticConfig::new(p).with_max_width(4).with_recording(),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    let mut sum = 0i64;
                    for i in 0..1_500i64 {
                        if tid == 0 && i % 100 == 0 {
                            f.resize(1 + (i as usize / 100) % 4);
                        }
                        let d = if (tid as i64 + i) % 3 == 0 { -2 } else { 5 };
                        f.fetch_add(tid, d);
                        sum += d;
                    }
                    sum
                })
            })
            .collect();
        let expected: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(f.read(0) as i64, expected, "sum conservation (Invariant 3.3)");
        let (history, recorded) = f.extract_history();
        assert_eq!(history.ops(), p * 1_500);
        let want = crate::runtime::batch_returns_cpu(&history);
        assert_eq!(want, recorded, "Lemma 3.4 with elastic resizes");
    }

    #[test]
    fn batch_stats_account_under_elasticity() {
        let p = 8;
        let f = Arc::new(ElasticAggFunnel::with_config(
            ElasticConfig::new(p).with_max_width(8).with_policy(WidthPolicy::Fixed(2)),
        ));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for i in 0..2_000usize {
                        if tid == 1 && i % 500 == 0 {
                            f.resize(1 + i / 500);
                        }
                        f.fetch_add(tid, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = f.batch_stats();
        assert_eq!(stats.ops, 8 * 2_000);
        assert!(stats.main_faas <= stats.ops);
        assert!(stats.main_faas > 0);
        assert!(stats.avg_batch_size() >= 1.0);
        assert!(stats.single_op_batches <= stats.main_faas);
    }
}
