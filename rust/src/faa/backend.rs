//! Backend specifications: one string grammar for every place a
//! fetch-and-add object is constructed from configuration — named
//! counters in the registry service, LCRQ ring-index factories, CLI
//! algorithm flags, and the `[objects]` manifest section.
//!
//! Grammar (case-sensitive, `:`-separated parameters):
//!
//! | Spec | Object |
//! |------|--------|
//! | `hw` | [`HardwareFaa`] — a single atomic word |
//! | `aggfunnel` / `aggfunnel:<m>` | [`AggFunnel`] with `m` Aggregators per sign (default 6) |
//! | `combfunnel` | [`CombiningFunnel`] baseline |
//! | `elastic` / `elastic:<policy>` | [`ElasticAggFunnel`] under a [`WidthPolicy`] (default `aimd`) |
//!
//! The `elastic` policy parameter reuses [`WidthPolicy::parse`], so
//! `elastic:fixed:4`, `elastic:sqrtp` and `elastic:aimd` all work.
//! Queue index backends compose this grammar with a queue family
//! (`lcrq+elastic:aimd` — see [`crate::queue::make_queue`]).

use std::sync::Arc;

use super::aggfunnel::{AggFunnel, AggFunnelConfig};
use super::combfunnel::CombiningFunnel;
use super::elastic::{ElasticAggFunnel, ElasticConfig};
use super::hardware::HardwareFaa;
use super::width::WidthPolicy;
use super::FetchAddObject;

/// Default Aggregator count (the paper's `m = 6`).
pub const DEFAULT_AGGREGATORS: usize = 6;
/// Default elastic slot capacity per sign.
pub const DEFAULT_MAX_WIDTH: usize = 12;

/// A parsed fetch-and-add backend specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackendSpec {
    /// Hardware F&A (one atomic word).
    Hw,
    /// Static Aggregating Funnel with `m` Aggregators per sign.
    Agg { m: usize },
    /// Combining Funnels baseline.
    Comb,
    /// Elastic Aggregating Funnel under a width policy.
    Elastic { policy: WidthPolicy, max_width: usize },
}

impl BackendSpec {
    /// Parse a backend-spec string; `None` on unknown spellings.
    pub fn parse(s: &str) -> Option<BackendSpec> {
        let s = s.trim();
        let (head, param) = match s.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (s, None),
        };
        match (head, param) {
            ("hw", None) => Some(BackendSpec::Hw),
            ("aggfunnel", None) => Some(BackendSpec::Agg { m: DEFAULT_AGGREGATORS }),
            ("aggfunnel", Some(m)) => {
                m.trim().parse().ok().map(|m: usize| BackendSpec::Agg { m: m.max(1) })
            }
            ("combfunnel", None) => Some(BackendSpec::Comb),
            ("elastic", None) => Some(BackendSpec::Elastic {
                policy: WidthPolicy::Aimd(Default::default()),
                max_width: DEFAULT_MAX_WIDTH,
            }),
            ("elastic", Some(p)) => WidthPolicy::parse(p)
                .map(|policy| BackendSpec::Elastic { policy, max_width: DEFAULT_MAX_WIDTH }),
            _ => None,
        }
    }

    /// Override the elastic slot capacity (no-op for static backends).
    pub fn with_max_width(mut self, w: usize) -> Self {
        if let BackendSpec::Elastic { max_width, .. } = &mut self {
            *max_width = w.max(1);
        }
        self
    }

    /// Canonical spelling, usable as a series label and re-parseable.
    pub fn label(&self) -> String {
        match self {
            BackendSpec::Hw => "hw".into(),
            BackendSpec::Agg { m } => format!("aggfunnel:{m}"),
            BackendSpec::Comb => "combfunnel".into(),
            BackendSpec::Elastic { policy, .. } => match policy {
                WidthPolicy::Fixed(m) => format!("elastic:fixed:{m}"),
                WidthPolicy::SqrtP => "elastic:sqrtp".into(),
                WidthPolicy::Aimd(_) => "elastic:aimd".into(),
            },
        }
    }

    /// Build the fetch-and-add object this spec describes.
    pub fn build(&self, max_threads: usize) -> Arc<dyn FetchAddObject> {
        match self {
            BackendSpec::Hw => Arc::new(HardwareFaa::new(max_threads)),
            BackendSpec::Agg { m } => Arc::new(AggFunnel::with_config(
                AggFunnelConfig::new(max_threads).with_aggregators(*m),
            )),
            BackendSpec::Comb => Arc::new(CombiningFunnel::new(max_threads)),
            BackendSpec::Elastic { policy, max_width } => {
                Arc::new(self::build_elastic(max_threads, *policy, *max_width))
            }
        }
    }

    /// The width policy (and slot capacity) a *counter object* built
    /// from this spec should run under. Registry counters always ride
    /// an [`ElasticAggFunnel`] (so `resize`/`policy`/width stats work
    /// uniformly); static specs pin the policy instead of changing the
    /// object type. `Hw`/`Comb` have no funnel width — `None`.
    pub fn counter_policy(&self) -> Option<(WidthPolicy, usize)> {
        match self {
            BackendSpec::Agg { m } => Some((WidthPolicy::Fixed(*m), (*m).max(1) * 2)),
            BackendSpec::Elastic { policy, max_width } => Some((*policy, *max_width)),
            BackendSpec::Hw | BackendSpec::Comb => None,
        }
    }
}

/// Build an elastic funnel with an explicit policy and capacity.
pub fn build_elastic(
    max_threads: usize,
    policy: WidthPolicy,
    max_width: usize,
) -> ElasticAggFunnel {
    ElasticAggFunnel::with_config(
        ElasticConfig::new(max_threads).with_max_width(max_width).with_policy(policy),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(BackendSpec::parse("hw"), Some(BackendSpec::Hw));
        assert_eq!(BackendSpec::parse("aggfunnel"), Some(BackendSpec::Agg { m: 6 }));
        assert_eq!(BackendSpec::parse("aggfunnel:4"), Some(BackendSpec::Agg { m: 4 }));
        assert_eq!(BackendSpec::parse("combfunnel"), Some(BackendSpec::Comb));
        assert!(matches!(
            BackendSpec::parse("elastic"),
            Some(BackendSpec::Elastic { policy: WidthPolicy::Aimd(_), max_width: 12 })
        ));
        assert_eq!(
            BackendSpec::parse("elastic:fixed:4"),
            Some(BackendSpec::Elastic { policy: WidthPolicy::Fixed(4), max_width: 12 })
        );
        assert_eq!(
            BackendSpec::parse("elastic:sqrtp"),
            Some(BackendSpec::Elastic { policy: WidthPolicy::SqrtP, max_width: 12 })
        );
        assert_eq!(BackendSpec::parse("nope"), None);
        assert_eq!(BackendSpec::parse("elastic:bogus"), None);
        assert_eq!(BackendSpec::parse("aggfunnel:x"), None);
    }

    #[test]
    fn labels_reparse() {
        for spec in [
            BackendSpec::Hw,
            BackendSpec::Agg { m: 4 },
            BackendSpec::Comb,
            BackendSpec::Elastic { policy: WidthPolicy::SqrtP, max_width: 12 },
            BackendSpec::Elastic { policy: WidthPolicy::Fixed(3), max_width: 12 },
        ] {
            assert_eq!(BackendSpec::parse(&spec.label()), Some(spec), "{}", spec.label());
        }
    }

    #[test]
    fn built_objects_count_correctly() {
        for spec in ["hw", "aggfunnel:2", "combfunnel", "elastic:fixed:2"] {
            let f = BackendSpec::parse(spec).unwrap().build(2);
            assert_eq!(f.fetch_add(0, 5), 0, "{spec}");
            assert_eq!(f.fetch_add(1, 3), 5, "{spec}");
            assert_eq!(f.read(0), 8, "{spec}");
        }
    }

    #[test]
    fn counter_policy_mapping() {
        assert_eq!(
            BackendSpec::parse("aggfunnel:4").unwrap().counter_policy(),
            Some((WidthPolicy::Fixed(4), 8))
        );
        let (policy, w) = BackendSpec::parse("elastic:sqrtp").unwrap().counter_policy().unwrap();
        assert_eq!(policy, WidthPolicy::SqrtP);
        assert_eq!(w, 12);
        assert_eq!(BackendSpec::Hw.counter_policy(), None);
    }

    #[test]
    fn max_width_override() {
        let spec = BackendSpec::parse("elastic:aimd").unwrap().with_max_width(5);
        assert_eq!(spec.counter_policy().unwrap().1, 5);
        // No-op on static backends.
        assert_eq!(BackendSpec::Hw.with_max_width(5), BackendSpec::Hw);
    }
}
