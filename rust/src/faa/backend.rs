//! Backend specifications: one string grammar for every place a
//! fetch-and-add object is constructed from configuration — named
//! counters in the registry service, LCRQ ring-index factories, CLI
//! algorithm flags, and the `[objects]` manifest section.
//!
//! Grammar (case-sensitive, `:`-separated parameters):
//!
//! | Spec | Object |
//! |------|--------|
//! | `hw` | [`HardwareFaa`] — a single atomic word |
//! | `aggfunnel` / `aggfunnel:<m>` | [`AggFunnel`] with `m` Aggregators per sign (default 6) |
//! | `combfunnel` | [`CombiningFunnel`] baseline |
//! | `elastic` / `elastic:<policy>` | [`ElasticAggFunnel`] under a [`WidthPolicy`] (default `aimd`) |
//!
//! The `elastic` policy parameter reuses [`WidthPolicy::parse`], so
//! `elastic:fixed:4`, `elastic:sqrtp` and `elastic:aimd` all work.
//! Queue index backends compose this grammar with a queue family
//! (`lcrq+elastic:aimd` — see [`crate::queue::make_queue`]).
//!
//! Funnelled specs (`aggfunnel`, `elastic`) accept an optional
//! trailing `:d<k>` segment — the §4.4 **direct quota**: at most `k`
//! callers may ride `Fetch&AddDirect` concurrently; callers beyond
//! the quota are demoted to the funnelled path. `aggfunnel:4:d2` and
//! `elastic:aimd:d1` parse; without the segment the quota is
//! unlimited (every priority request goes direct, the pre-quota
//! behaviour). [`BackendSpec::build`] enforces the quota with a
//! [`DirectQuota`] gate, and the registry service gates per object
//! with the same [`DirectPermits`], so the suffix means one thing
//! everywhere. The paper's AGGFUNNEL-(m,d) *designated-thread*
//! variant (threads `tid < d` bypass the funnel on plain
//! `fetch_add`) is a separate mechanism, configured via
//! [`AggFunnelConfig::with_direct_threads`].
//!
//! Funnelled specs also accept a trailing `:b<policy>` segment — the
//! **CAS retry policy** ([`RetryPolicy`]: `none` / `const` / `exp` /
//! `adaptive`) governing every hot CAS loop inside the object (funnel
//! restart arbitration, the permit gate's CAS loop, and — through the
//! queue grammar — CRQ ring retries). `elastic:aimd:bexp` and
//! `aggfunnel:4:d2:badaptive` parse; canonical order is `:d` before
//! `:b`. `hw`/`combfunnel` reject the suffix like they reject `:d`.
//! Without the segment the object runs under the caller's default
//! (the service's `[service] cas_policy`, itself `adaptive`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::aggfunnel::{AggFunnel, AggFunnelConfig};
use super::combfunnel::CombiningFunnel;
use super::elastic::{ElasticAggFunnel, ElasticConfig};
use super::hardware::HardwareFaa;
use super::width::WidthPolicy;
use super::{BatchStats, FetchAddObject};
use crate::sync::{CasCtl, RetryPolicy};

/// Default Aggregator count (the paper's `m = 6`).
pub const DEFAULT_AGGREGATORS: usize = 6;
/// Default elastic slot capacity per sign.
pub const DEFAULT_MAX_WIDTH: usize = 12;

/// A parsed fetch-and-add backend specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackendSpec {
    /// Hardware F&A (one atomic word).
    Hw,
    /// Static Aggregating Funnel with `m` Aggregators per sign, an
    /// optional §4.4 direct-thread quota (`None` = unlimited) and an
    /// optional CAS retry policy (`None` = caller's default).
    Agg { m: usize, direct: Option<usize>, cas: Option<RetryPolicy> },
    /// Combining Funnels baseline.
    Comb,
    /// Elastic Aggregating Funnel under a width policy, with an
    /// optional §4.4 direct-thread quota (`None` = unlimited) and an
    /// optional CAS retry policy (`None` = caller's default).
    Elastic {
        policy: WidthPolicy,
        max_width: usize,
        direct: Option<usize>,
        cas: Option<RetryPolicy>,
    },
}

impl BackendSpec {
    /// Parse a backend-spec string; `None` on unknown spellings.
    pub fn parse(s: &str) -> Option<BackendSpec> {
        // Suffix order mirrors the canonical label: `...:d<k>:b<policy>`,
        // so `:b` is stripped first.
        let (s, cas) = split_cas_policy(s.trim());
        let (s, direct) = split_direct_quota(s);
        let (head, param) = match s.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (s, None),
        };
        let spec = match (head, param) {
            ("hw", None) => Some(BackendSpec::Hw),
            ("aggfunnel", None) => {
                Some(BackendSpec::Agg { m: DEFAULT_AGGREGATORS, direct, cas })
            }
            ("aggfunnel", Some(m)) => m
                .trim()
                .parse()
                .ok()
                .map(|m: usize| BackendSpec::Agg { m: m.max(1), direct, cas }),
            ("combfunnel", None) => Some(BackendSpec::Comb),
            ("elastic", None) => Some(BackendSpec::Elastic {
                policy: WidthPolicy::Aimd(Default::default()),
                max_width: DEFAULT_MAX_WIDTH,
                direct,
                cas,
            }),
            ("elastic", Some(p)) => WidthPolicy::parse(p).map(|policy| BackendSpec::Elastic {
                policy,
                max_width: DEFAULT_MAX_WIDTH,
                direct,
                cas,
            }),
            _ => None,
        };
        // `:d<k>` / `:b<policy>` on a backend with no funnel is a
        // parse error, not a silently dropped parameter.
        match spec {
            Some(BackendSpec::Hw | BackendSpec::Comb) if direct.is_some() || cas.is_some() => {
                None
            }
            other => other,
        }
    }

    /// Override the elastic slot capacity (no-op for static backends).
    pub fn with_max_width(mut self, w: usize) -> Self {
        if let BackendSpec::Elastic { max_width, .. } = &mut self {
            *max_width = w.max(1);
        }
        self
    }

    /// Set the §4.4 direct-thread quota (no-op for `hw`/`combfunnel`,
    /// which have no funnel to bypass).
    pub fn with_direct_quota(mut self, d: usize) -> Self {
        match &mut self {
            BackendSpec::Agg { direct, .. } | BackendSpec::Elastic { direct, .. } => {
                *direct = Some(d);
            }
            BackendSpec::Hw | BackendSpec::Comb => {}
        }
        self
    }

    /// The §4.4 direct-thread quota: `Some(d)` when configured,
    /// `None` for unlimited (or for backends with no funnel).
    pub fn direct_quota(&self) -> Option<usize> {
        match self {
            BackendSpec::Agg { direct, .. } | BackendSpec::Elastic { direct, .. } => *direct,
            BackendSpec::Hw | BackendSpec::Comb => None,
        }
    }

    /// Set the CAS retry policy (no-op for `hw`/`combfunnel`, which
    /// have no guarded CAS loops).
    pub fn with_cas_policy(mut self, p: RetryPolicy) -> Self {
        match &mut self {
            BackendSpec::Agg { cas, .. } | BackendSpec::Elastic { cas, .. } => {
                *cas = Some(p);
            }
            BackendSpec::Hw | BackendSpec::Comb => {}
        }
        self
    }

    /// The CAS retry policy: `Some(p)` when the spec pins one,
    /// `None` for "use the caller's default".
    pub fn cas_policy(&self) -> Option<RetryPolicy> {
        match self {
            BackendSpec::Agg { cas, .. } | BackendSpec::Elastic { cas, .. } => *cas,
            BackendSpec::Hw | BackendSpec::Comb => None,
        }
    }

    /// Canonical spelling, usable as a series label and re-parseable.
    pub fn label(&self) -> String {
        let mut label = match self {
            BackendSpec::Hw => "hw".to_string(),
            BackendSpec::Agg { m, .. } => format!("aggfunnel:{m}"),
            BackendSpec::Comb => "combfunnel".to_string(),
            BackendSpec::Elastic { policy, .. } => match policy {
                WidthPolicy::Fixed(m) => format!("elastic:fixed:{m}"),
                WidthPolicy::SqrtP => "elastic:sqrtp".to_string(),
                WidthPolicy::Aimd(_) => "elastic:aimd".to_string(),
            },
        };
        if let Some(d) = self.direct_quota() {
            label.push_str(&format!(":d{d}"));
        }
        if let Some(p) = self.cas_policy() {
            label.push_str(&format!(":b{}", p.label()));
        }
        label
    }

    /// Build the fetch-and-add object this spec describes. A `:d<k>`
    /// direct quota wraps the funnel in a [`DirectQuota`] gate — at
    /// most `k` concurrent `fetch_add_direct` callers ride `Main`,
    /// the rest demoted to the funnel — so the quota is enforced for
    /// standalone builds exactly as the registry service enforces it
    /// per object, with the same semantics for `aggfunnel` and
    /// `elastic`. (The paper's AGGFUNNEL-(m,d) *designated-thread*
    /// construction — plain `fetch_add` of threads `tid < d` going
    /// straight to `Main`, with no concurrency gate — is a different
    /// mechanism and stays available programmatically via
    /// [`AggFunnelConfig::with_direct_threads`]; composing both in
    /// one object would double the number of callers allowed on
    /// `Main`.)
    pub fn build(&self, max_threads: usize) -> Arc<dyn FetchAddObject> {
        match self {
            BackendSpec::Hw => Arc::new(HardwareFaa::new(max_threads)),
            BackendSpec::Agg { m, direct, cas } => {
                let mut cfg = AggFunnelConfig::new(max_threads).with_aggregators(*m);
                if let Some(p) = cas {
                    cfg = cfg.with_cas_policy(*p);
                }
                let funnel = AggFunnel::with_config(cfg);
                match direct {
                    Some(d) => Arc::new(DirectQuota::with_policy(
                        funnel,
                        *d,
                        cas.unwrap_or_default(),
                    )),
                    None => Arc::new(funnel),
                }
            }
            BackendSpec::Comb => Arc::new(CombiningFunnel::new(max_threads)),
            BackendSpec::Elastic { policy, max_width, direct, cas } => {
                let funnel = self::build_elastic(max_threads, *policy, *max_width);
                if let Some(p) = cas {
                    funnel.set_cas_policy(*p);
                }
                match direct {
                    Some(d) => Arc::new(DirectQuota::with_policy(
                        funnel,
                        *d,
                        cas.unwrap_or_default(),
                    )),
                    None => Arc::new(funnel),
                }
            }
        }
    }

    /// The width policy (and slot capacity) a *counter object* built
    /// from this spec should run under. Registry counters always ride
    /// an [`ElasticAggFunnel`] (so `resize`/`policy`/width stats work
    /// uniformly); static specs pin the policy instead of changing the
    /// object type. `Hw`/`Comb` have no funnel width — `None`.
    pub fn counter_policy(&self) -> Option<(WidthPolicy, usize)> {
        match self {
            BackendSpec::Agg { m, .. } => Some((WidthPolicy::Fixed(*m), (*m).max(1) * 2)),
            BackendSpec::Elastic { policy, max_width, .. } => Some((*policy, *max_width)),
            BackendSpec::Hw | BackendSpec::Comb => None,
        }
    }
}

/// Split a trailing `:d<k>` direct-quota segment off a spec string.
fn split_direct_quota(s: &str) -> (&str, Option<usize>) {
    if let Some((head, tail)) = s.rsplit_once(":d") {
        if !tail.is_empty() && tail.bytes().all(|b| b.is_ascii_digit()) {
            if let Ok(d) = tail.parse() {
                return (head, Some(d));
            }
        }
    }
    (s, None)
}

/// Split a trailing `:b<policy>` CAS-retry-policy segment off a spec
/// string. Only a tail that is exactly a [`RetryPolicy`] spelling is
/// consumed, so `elastic:fixed:3` (no `:b`) and malformed tails pass
/// through untouched for the main grammar to reject.
fn split_cas_policy(s: &str) -> (&str, Option<RetryPolicy>) {
    if let Some((head, tail)) = s.rsplit_once(":b") {
        if let Some(p) = RetryPolicy::parse(tail) {
            return (head, Some(p));
        }
    }
    (s, None)
}

/// Permit counter for §4.4 direct access: at most `quota` concurrent
/// holders. Acquisition is a CAS loop on one word — callers that
/// lose the race are expected to fall back to the funnelled path,
/// they never spin on a full gate, but concurrent acquirers *do*
/// collide on the permit word, so the loop is paced by a
/// [`CasCtl`]. Shared by [`DirectQuota`] and the registry service's
/// per-object gate so the protocol exists exactly once.
pub struct DirectPermits {
    quota: usize,
    in_flight: AtomicUsize,
    cas: CasCtl,
}

impl DirectPermits {
    pub fn new(quota: usize) -> Self {
        Self::with_policy(quota, RetryPolicy::default())
    }

    /// A gate whose permit-word CAS loop runs under `policy`.
    pub fn with_policy(quota: usize, policy: RetryPolicy) -> Self {
        Self { quota, in_flight: AtomicUsize::new(0), cas: CasCtl::new(policy) }
    }

    /// The configured quota `d`.
    pub fn quota(&self) -> usize {
        self.quota
    }

    /// Swap the retry policy pacing the permit-word CAS loop.
    pub fn set_cas_policy(&self, policy: RetryPolicy) {
        self.cas.set(policy);
    }

    /// The retry policy currently pacing the permit-word CAS loop.
    pub fn cas_policy(&self) -> RetryPolicy {
        self.cas.get()
    }

    /// Try to claim one of the `quota` direct slots.
    pub fn try_acquire(&self) -> bool {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        let mut retry = self.cas.retry(cur as u64);
        loop {
            if cur >= self.quota {
                return false;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    retry.on_success();
                    return true;
                }
                Err(now) => {
                    cur = now;
                    retry.on_fail();
                }
            }
        }
    }

    /// Return a slot claimed by [`DirectPermits::try_acquire`].
    pub fn release(&self) {
        self.in_flight.fetch_sub(1, Ordering::Release);
    }
}

/// Enforces a §4.4 direct-thread quota around a funnelled object: at
/// most `quota` callers ride `Fetch&AddDirect` on `Main`
/// concurrently; excess callers are demoted to the funnelled
/// `fetch_add` path (they never spin). Every other operation passes
/// straight through.
pub struct DirectQuota<T: FetchAddObject> {
    inner: T,
    permits: DirectPermits,
}

impl<T: FetchAddObject> DirectQuota<T> {
    pub fn new(inner: T, quota: usize) -> Self {
        Self { inner, permits: DirectPermits::new(quota) }
    }

    /// A gated object whose permit CAS loop runs under `policy` (the
    /// inner object keeps whatever policy it was built with).
    pub fn with_policy(inner: T, quota: usize, policy: RetryPolicy) -> Self {
        Self { inner, permits: DirectPermits::with_policy(quota, policy) }
    }
}

impl<T: FetchAddObject> FetchAddObject for DirectQuota<T> {
    #[inline]
    fn fetch_add(&self, tid: usize, delta: i64) -> u64 {
        self.inner.fetch_add(tid, delta)
    }

    #[inline]
    fn read(&self, tid: usize) -> u64 {
        self.inner.read(tid)
    }

    fn fetch_add_direct(&self, tid: usize, delta: i64) -> u64 {
        if !self.permits.try_acquire() {
            // Quota exhausted: demote to the funnel instead of
            // overloading `Main`.
            return self.inner.fetch_add(tid, delta);
        }
        let v = self.inner.fetch_add_direct(tid, delta);
        self.permits.release();
        v
    }

    #[inline]
    fn compare_and_swap(&self, tid: usize, old: u64, new: u64) -> u64 {
        self.inner.compare_and_swap(tid, old, new)
    }

    #[inline]
    fn fetch_or(&self, tid: usize, bits: u64) -> u64 {
        self.inner.fetch_or(tid, bits)
    }

    fn max_threads(&self) -> usize {
        self.inner.max_threads()
    }

    fn batch_stats(&self) -> BatchStats {
        self.inner.batch_stats()
    }

    fn set_cas_policy(&self, policy: RetryPolicy) {
        self.permits.set_cas_policy(policy);
        self.inner.set_cas_policy(policy);
    }

    fn cas_policy(&self) -> Option<RetryPolicy> {
        self.inner.cas_policy().or(Some(self.permits.cas_policy()))
    }
}

/// Build an elastic funnel with an explicit policy and capacity.
pub fn build_elastic(
    max_threads: usize,
    policy: WidthPolicy,
    max_width: usize,
) -> ElasticAggFunnel {
    ElasticAggFunnel::with_config(
        ElasticConfig::new(max_threads).with_max_width(max_width).with_policy(policy),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(BackendSpec::parse("hw"), Some(BackendSpec::Hw));
        assert_eq!(
            BackendSpec::parse("aggfunnel"),
            Some(BackendSpec::Agg { m: 6, direct: None, cas: None })
        );
        assert_eq!(
            BackendSpec::parse("aggfunnel:4"),
            Some(BackendSpec::Agg { m: 4, direct: None, cas: None })
        );
        assert_eq!(BackendSpec::parse("combfunnel"), Some(BackendSpec::Comb));
        assert!(matches!(
            BackendSpec::parse("elastic"),
            Some(BackendSpec::Elastic {
                policy: WidthPolicy::Aimd(_),
                max_width: 12,
                direct: None,
                cas: None,
            })
        ));
        assert_eq!(
            BackendSpec::parse("elastic:fixed:4"),
            Some(BackendSpec::Elastic {
                policy: WidthPolicy::Fixed(4),
                max_width: 12,
                direct: None,
                cas: None,
            })
        );
        assert_eq!(
            BackendSpec::parse("elastic:sqrtp"),
            Some(BackendSpec::Elastic {
                policy: WidthPolicy::SqrtP,
                max_width: 12,
                direct: None,
                cas: None,
            })
        );
        assert_eq!(BackendSpec::parse("nope"), None);
        assert_eq!(BackendSpec::parse("elastic:bogus"), None);
        assert_eq!(BackendSpec::parse("aggfunnel:x"), None);
    }

    #[test]
    fn parse_direct_quota_segment() {
        assert_eq!(
            BackendSpec::parse("aggfunnel:4:d2"),
            Some(BackendSpec::Agg { m: 4, direct: Some(2), cas: None })
        );
        assert_eq!(
            BackendSpec::parse("aggfunnel:d1"),
            Some(BackendSpec::Agg { m: 6, direct: Some(1), cas: None })
        );
        assert_eq!(
            BackendSpec::parse("elastic:sqrtp:d0"),
            Some(BackendSpec::Elastic {
                policy: WidthPolicy::SqrtP,
                max_width: 12,
                direct: Some(0),
                cas: None,
            })
        );
        assert_eq!(
            BackendSpec::parse("elastic:fixed:3:d2"),
            Some(BackendSpec::Elastic {
                policy: WidthPolicy::Fixed(3),
                max_width: 12,
                direct: Some(2),
                cas: None,
            })
        );
        assert!(matches!(
            BackendSpec::parse("elastic:d2"),
            Some(BackendSpec::Elastic { policy: WidthPolicy::Aimd(_), direct: Some(2), .. })
        ));
        // No funnel to bypass → no quota parameter.
        assert_eq!(BackendSpec::parse("hw:d1"), None);
        assert_eq!(BackendSpec::parse("combfunnel:d1"), None);
        // Malformed quotas fail the whole spec.
        assert_eq!(BackendSpec::parse("aggfunnel:4:d"), None);
        assert_eq!(BackendSpec::parse("aggfunnel:4:dx"), None);
    }

    #[test]
    fn parse_cas_policy_segment() {
        assert_eq!(
            BackendSpec::parse("aggfunnel:4:bexp"),
            Some(BackendSpec::Agg { m: 4, direct: None, cas: Some(RetryPolicy::Exp) })
        );
        assert_eq!(
            BackendSpec::parse("aggfunnel:4:d2:badaptive"),
            Some(BackendSpec::Agg {
                m: 4,
                direct: Some(2),
                cas: Some(RetryPolicy::Adaptive),
            })
        );
        assert_eq!(
            BackendSpec::parse("elastic:fixed:3:bnone"),
            Some(BackendSpec::Elastic {
                policy: WidthPolicy::Fixed(3),
                max_width: 12,
                direct: None,
                cas: Some(RetryPolicy::None),
            })
        );
        assert!(matches!(
            BackendSpec::parse("elastic:bconst"),
            Some(BackendSpec::Elastic { cas: Some(RetryPolicy::Constant), direct: None, .. })
        ));
        // No guarded loops → no policy parameter (ISSUE: hw must reject).
        assert_eq!(BackendSpec::parse("hw:bexp"), None);
        assert_eq!(BackendSpec::parse("combfunnel:badaptive"), None);
        // Malformed policies fail the whole spec.
        assert_eq!(BackendSpec::parse("aggfunnel:4:b"), None);
        assert_eq!(BackendSpec::parse("aggfunnel:4:bzzz"), None);
        // `:b` must come after `:d` (canonical order only).
        assert_eq!(BackendSpec::parse("aggfunnel:4:bexp:d2"), None);
    }

    #[test]
    fn labels_reparse() {
        for spec in [
            BackendSpec::Hw,
            BackendSpec::Agg { m: 4, direct: None, cas: None },
            BackendSpec::Agg { m: 4, direct: Some(2), cas: None },
            BackendSpec::Agg { m: 4, direct: Some(2), cas: Some(RetryPolicy::Exp) },
            BackendSpec::Agg { m: 4, direct: None, cas: Some(RetryPolicy::None) },
            BackendSpec::Comb,
            BackendSpec::Elastic {
                policy: WidthPolicy::SqrtP,
                max_width: 12,
                direct: None,
                cas: None,
            },
            BackendSpec::Elastic {
                policy: WidthPolicy::Fixed(3),
                max_width: 12,
                direct: Some(1),
                cas: Some(RetryPolicy::Adaptive),
            },
            BackendSpec::Elastic {
                policy: WidthPolicy::SqrtP,
                max_width: 12,
                direct: None,
                cas: Some(RetryPolicy::Constant),
            },
        ] {
            assert_eq!(BackendSpec::parse(&spec.label()), Some(spec), "{}", spec.label());
        }
    }

    #[test]
    fn cas_policy_accessors_and_build() {
        let spec = BackendSpec::parse("elastic:aimd").unwrap().with_cas_policy(RetryPolicy::Exp);
        assert_eq!(spec.cas_policy(), Some(RetryPolicy::Exp));
        assert_eq!(spec.label(), "elastic:aimd:bexp");
        assert_eq!(BackendSpec::Hw.with_cas_policy(RetryPolicy::Exp).cas_policy(), None);
        // The built object carries the policy and stays live-swappable.
        for raw in ["elastic:fixed:2:bnone", "aggfunnel:2:bconst", "elastic:aimd:d1:bexp"] {
            let spec = BackendSpec::parse(raw).unwrap();
            let f = spec.build(2);
            assert_eq!(f.cas_policy(), spec.cas_policy(), "{raw}");
            assert_eq!(f.fetch_add(0, 5), 0, "{raw}");
            assert_eq!(f.read(1), 5, "{raw}");
            f.set_cas_policy(RetryPolicy::Adaptive);
            assert_eq!(f.cas_policy(), Some(RetryPolicy::Adaptive), "{raw}");
        }
    }

    #[test]
    fn direct_quota_accessors() {
        let spec = BackendSpec::parse("elastic:aimd").unwrap().with_direct_quota(2);
        assert_eq!(spec.direct_quota(), Some(2));
        assert_eq!(spec.label(), "elastic:aimd:d2");
        assert_eq!(BackendSpec::Hw.with_direct_quota(2).direct_quota(), None);
    }

    #[test]
    fn agg_build_gates_directs_like_elastic() {
        // The `:d<k>` suffix means the same thing on every funnelled
        // backend: a concurrency quota on explicit directs. Plain
        // fetch_add is untouched and everything still counts.
        let f = BackendSpec::parse("aggfunnel:2:d1").unwrap().build(2);
        assert_eq!(f.fetch_add(0, 5), 0);
        assert_eq!(f.fetch_add(1, 3), 5);
        assert_eq!(f.fetch_add_direct(0, 2), 8);
        assert_eq!(f.read(0), 10);
        // Quota 0 demotes explicit directs to the funnel; the result
        // is still linearizable.
        let gated = BackendSpec::parse("aggfunnel:2:d0").unwrap().build(2);
        assert_eq!(gated.fetch_add_direct(0, 7), 0);
        assert_eq!(gated.read(1), 7);
    }

    #[test]
    fn elastic_build_enforces_direct_quota() {
        // Quota 0: fetch_add_direct demotes to the funnel, visible as
        // a single-op batch (a true direct records no batch at all).
        let gated = BackendSpec::parse("elastic:fixed:1:d0").unwrap().build(2);
        assert_eq!(gated.fetch_add_direct(0, 5), 0);
        assert_eq!(gated.read(1), 5);
        let s = gated.batch_stats();
        assert!(s.single_op_batches >= 1, "demoted direct must go through the funnel: {s:?}");

        let open = BackendSpec::parse("elastic:fixed:1").unwrap().build(2);
        assert_eq!(open.fetch_add_direct(0, 5), 0);
        assert_eq!(
            open.batch_stats().single_op_batches,
            0,
            "unlimited direct bypasses the funnel"
        );

        // A positive quota admits directs again.
        let one = BackendSpec::parse("elastic:fixed:1:d1").unwrap().build(2);
        assert_eq!(one.fetch_add_direct(0, 2), 0);
        assert_eq!(one.fetch_add_direct(1, 3), 2);
        assert_eq!(one.read(0), 5);
        assert_eq!(one.batch_stats().single_op_batches, 0, "sequential directs fit quota 1");
    }

    #[test]
    fn built_objects_count_correctly() {
        for spec in ["hw", "aggfunnel:2", "combfunnel", "elastic:fixed:2"] {
            let f = BackendSpec::parse(spec).unwrap().build(2);
            assert_eq!(f.fetch_add(0, 5), 0, "{spec}");
            assert_eq!(f.fetch_add(1, 3), 5, "{spec}");
            assert_eq!(f.read(0), 8, "{spec}");
        }
    }

    #[test]
    fn counter_policy_mapping() {
        assert_eq!(
            BackendSpec::parse("aggfunnel:4").unwrap().counter_policy(),
            Some((WidthPolicy::Fixed(4), 8))
        );
        // The quota is orthogonal to the width policy.
        assert_eq!(
            BackendSpec::parse("aggfunnel:4:d2").unwrap().counter_policy(),
            Some((WidthPolicy::Fixed(4), 8))
        );
        let (policy, w) = BackendSpec::parse("elastic:sqrtp").unwrap().counter_policy().unwrap();
        assert_eq!(policy, WidthPolicy::SqrtP);
        assert_eq!(w, 12);
        assert_eq!(BackendSpec::Hw.counter_policy(), None);
    }

    #[test]
    fn direct_permits_policy_knob() {
        let permits = DirectPermits::with_policy(1, RetryPolicy::None);
        assert_eq!(permits.cas_policy(), RetryPolicy::None);
        assert!(permits.try_acquire());
        assert!(!permits.try_acquire(), "quota 1 admits one holder");
        permits.release();
        permits.set_cas_policy(RetryPolicy::Exp);
        assert_eq!(permits.cas_policy(), RetryPolicy::Exp);
        assert!(permits.try_acquire(), "policy swap must not leak permits");
        permits.release();
    }

    #[test]
    fn max_width_override() {
        let spec = BackendSpec::parse("elastic:aimd").unwrap().with_max_width(5);
        assert_eq!(spec.counter_policy().unwrap().1, 5);
        // No-op on static backends.
        assert_eq!(BackendSpec::Hw.with_max_width(5), BackendSpec::Hw);
    }
}
