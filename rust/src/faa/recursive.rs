//! The §3.2 recursive construction.
//!
//! "We can replace `Main` or any of the Aggregators' `value` fields by
//! an instance of Algorithm 1." Because [`super::aggfunnel::AggFunnel`]
//! is generic over its [`super::aggfunnel::MainCell`], the recursive
//! variant is simply `AggFunnel<AggFunnel<AtomicMain>>`: the outer
//! funnel's delegates perform their batch F&A *through* the inner
//! funnel instead of on a raw atomic word. With `m` outer and `m'`
//! inner Aggregators, contention is `p/m` per outer Aggregator, `m/m'`
//! per inner Aggregator and `m'` on the innermost `Main`.
//!
//! The paper's best-performing recursive configuration (§4.3) uses
//! `m = ⌈p/6⌉` outer Aggregators and an inner funnel with `m' = 6`;
//! [`RecursiveAggFunnel::paper_config`] builds exactly that.

use super::aggfunnel::{AggFunnel, AggFunnelConfig, AtomicMain};
use super::{BatchStats, FetchAddObject};

/// A two-level Aggregating Funnel (outer funnel whose `Main` is an
/// inner funnel). Deeper recursion can be built the same way by hand;
/// the paper found a single replacement already does not pay off below
/// p = 176, so two levels is what the evaluation needs.
pub struct RecursiveAggFunnel {
    outer: AggFunnel<AggFunnel<AtomicMain>>,
}

impl RecursiveAggFunnel {
    /// Build with explicit outer/inner Aggregator counts.
    pub fn new(max_threads: usize, outer_m: usize, inner_m: usize) -> Self {
        let inner_cfg = AggFunnelConfig::new(max_threads).with_aggregators(inner_m);
        let inner = AggFunnel::with_main(inner_cfg, AtomicMain::new(0));
        let outer_cfg = AggFunnelConfig::new(max_threads).with_aggregators(outer_m);
        Self { outer: AggFunnel::with_main(outer_cfg, inner) }
    }

    /// §4.3's best recursive variant: `m = ⌈p/6⌉` outer, `m' = 6` inner.
    pub fn paper_config(max_threads: usize) -> Self {
        let outer_m = max_threads.div_ceil(6).max(1);
        Self::new(max_threads, outer_m, 6)
    }

    /// The §3.2 "balanced thirds" configuration: `m = p^(2/3)` outer,
    /// `m' = p^(1/3)` inner, giving O(p^(1/3)) contention everywhere.
    pub fn balanced_config(max_threads: usize) -> Self {
        let p = max_threads.max(1) as f64;
        let outer_m = (p.powf(2.0 / 3.0).round() as usize).max(1);
        let inner_m = (p.powf(1.0 / 3.0).round() as usize).max(1);
        Self::new(max_threads, outer_m, inner_m)
    }
}

impl FetchAddObject for RecursiveAggFunnel {
    fn fetch_add(&self, tid: usize, delta: i64) -> u64 {
        self.outer.fetch_add(tid, delta)
    }

    fn read(&self, tid: usize) -> u64 {
        self.outer.read(tid)
    }

    fn fetch_add_direct(&self, tid: usize, delta: i64) -> u64 {
        self.outer.fetch_add_direct(tid, delta)
    }

    fn compare_and_swap(&self, tid: usize, old: u64, new: u64) -> u64 {
        self.outer.compare_and_swap(tid, old, new)
    }

    fn fetch_or(&self, tid: usize, bits: u64) -> u64 {
        self.outer.fetch_or(tid, bits)
    }

    fn max_threads(&self) -> usize {
        self.outer.max_threads()
    }

    fn batch_stats(&self) -> BatchStats {
        // Outer-level stats: `ops` counts user operations; `main_faas`
        // counts batches pushed into the inner funnel.
        self.outer.batch_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_semantics() {
        let f = RecursiveAggFunnel::new(1, 2, 2);
        assert_eq!(f.fetch_add(0, 5), 0);
        assert_eq!(f.fetch_add(0, -1), 5);
        assert_eq!(f.read(0), 4);
        assert_eq!(f.compare_and_swap(0, 4, 10), 4);
        assert_eq!(f.read(0), 10);
    }

    #[test]
    fn paper_and_balanced_configs_build() {
        let f = RecursiveAggFunnel::paper_config(176);
        assert_eq!(f.max_threads(), 176);
        let g = RecursiveAggFunnel::balanced_config(8);
        assert_eq!(g.max_threads(), 8);
    }

    #[test]
    fn concurrent_fetch_inc_dense() {
        let p = 8;
        let f = Arc::new(RecursiveAggFunnel::new(p, 4, 2));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    (0..2_000).map(|_| f.fetch_add(tid, 1)).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..(p as u64 * 2_000)).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_mixed_signs_sum() {
        let p = 6;
        let f = Arc::new(RecursiveAggFunnel::new(p, 3, 2));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    for i in 0i64..3_000 {
                        f.fetch_add(tid, if i % 2 == 0 { 7 } else { -3 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let per: i64 = (0..3_000).map(|i| if i % 2 == 0 { 7 } else { -3 }).sum();
        assert_eq!(f.read(0), (6 * per) as u64);
    }
}
