//! Linearizable software fetch-and-add objects.
//!
//! The value domain is a 64-bit unsigned integer with wrap-around
//! (mod 2⁶⁴) semantics, exactly like the hardware `lock xadd`
//! instruction the paper's `Main` variable relies on. Deltas are
//! signed; a negative delta wraps, as the paper specifies ("we assume
//! the arithmetic in line 37 wraps around modulo 2⁶⁴").
//!
//! Implementations:
//!
//! * [`hardware::HardwareFaa`] — a single atomic word (the baseline the
//!   paper calls "hardware F&A").
//! * [`aggfunnel::AggFunnel`] — the paper's Aggregating Funnels
//!   (Algorithm 1), including overflow handling, `Fetch&AddDirect`,
//!   `Read`, `Compare&Swap` and `Fetch&Or` (RMWability).
//! * [`recursive`] — the §3.2 recursive construction (an `AggFunnel`
//!   whose `Main` is itself an `AggFunnel`).
//! * [`counter::AggCounter`] — the §3.1.2 Add/Read-only counter that
//!   needs no Batch objects.
//! * [`combfunnel::CombiningFunnel`] — the Combining Funnels baseline
//!   (Shavit & Zemach 2000) the paper compares against.
//! * [`combtree::CombiningTree`] — the classic software combining tree
//!   (related-work baseline, used in ablations).
//! * [`elastic::ElasticAggFunnel`] — Aggregating Funnels whose active
//!   Aggregator set grows and shrinks at runtime, driven by a
//!   [`width::WidthPolicy`] over a lock-free
//!   [`width::ContentionMonitor`] (this crate's extension beyond the
//!   paper; see `DESIGN.md`).
//! * [`backend::BackendSpec`] — the one-string construction grammar
//!   (`hw`, `aggfunnel:<m>`, `combfunnel`, `elastic:<policy>`) shared
//!   by the registry service, the queue index factories and the CLI.

pub mod aggfunnel;
pub mod backend;
pub mod choose;
pub mod combfunnel;
pub mod combtree;
pub mod counter;
pub mod elastic;
pub mod hardware;
pub mod recursive;
pub mod width;

pub use aggfunnel::{AggFunnel, AggFunnelConfig};
pub use backend::BackendSpec;
pub use choose::Choose;
pub use combfunnel::{CombiningFunnel, CombiningFunnelConfig};
pub use combtree::CombiningTree;
pub use counter::AggCounter;
pub use elastic::{ElasticAggFunnel, ElasticConfig};
pub use hardware::HardwareFaa;
pub use recursive::RecursiveAggFunnel;
pub use width::{AimdParams, ContentionMonitor, ContentionSnapshot, WidthPolicy};

/// Fold a signed delta into the unsigned wrap-around domain.
#[inline]
pub fn delta_to_u64(delta: i64) -> u64 {
    delta as u64 // two's-complement: wrapping add of this IS adding delta mod 2^64
}

/// A linearizable 64-bit fetch-and-add object (the paper's object `O`).
///
/// Every method takes the caller's thread id `tid`
/// (`0 <= tid < max_threads()`); each tid must be used by at most one
/// OS thread at a time. Implementations use it for Aggregator
/// selection, epoch-based reclamation and per-thread scratch state.
pub trait FetchAddObject: Send + Sync {
    /// Atomically add `delta` (mod 2⁶⁴) and return the previous value.
    fn fetch_add(&self, tid: usize, delta: i64) -> u64;

    /// Return the current value (paper: `Read`, i.e. `Fetch&Add(0)`).
    fn read(&self, tid: usize) -> u64;

    /// Apply the add directly to `Main`, bypassing any combining — the
    /// paper's `Fetch&AddDirect` for high-priority threads. For
    /// implementations without combining this is `fetch_add`.
    fn fetch_add_direct(&self, tid: usize, delta: i64) -> u64 {
        self.fetch_add(tid, delta)
    }

    /// RMWability (§3, "Our implementation is RMWable"): hardware
    /// compare-and-swap applied to the object. Returns the witnessed
    /// value (equal to `old` iff the CAS succeeded).
    fn compare_and_swap(&self, tid: usize, old: u64, new: u64) -> u64;

    /// RMWability: atomic OR applied to the object; returns the prior
    /// value. (LCRQ uses this to set the ring-closed bit.)
    fn fetch_or(&self, tid: usize, bits: u64) -> u64;

    /// Upper bound on thread ids this object was built for.
    fn max_threads(&self) -> usize;

    /// Implementation-specific statistics for the benchmark harness:
    /// `(batches_applied, ops_batched)`, used to derive the paper's
    /// *average batch size* metric (§4.1). Non-combining
    /// implementations report every op as its own batch.
    fn batch_stats(&self) -> BatchStats {
        BatchStats::default()
    }

    /// Swap the [`crate::sync::RetryPolicy`] pacing this object's
    /// contended CAS loops (funnel restart arbitration, permit gates).
    /// Default no-op for implementations with no guarded loops.
    fn set_cas_policy(&self, _policy: crate::sync::RetryPolicy) {}

    /// The CAS retry policy in force, `None` for implementations with
    /// no guarded loops.
    fn cas_policy(&self) -> Option<crate::sync::RetryPolicy> {
        None
    }
}

/// Counters backing the paper's "average batch size" metric, plus the
/// contention signals the adaptive-width subsystem samples
/// ([`width::ContentionMonitor`] folds its window counters in here so
/// every consumer reads one record).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Number of F&A instructions applied to `Main` (batches plus
    /// direct operations).
    pub main_faas: u64,
    /// Number of `Fetch&Add` operations those F&As accomplished.
    pub ops: u64,
    /// Batches that combined exactly one operation (no batching win);
    /// the AIMD shrink signal. Zero for implementations that do not
    /// track it.
    pub single_op_batches: u64,
    /// Failed `Compare&Swap` attempts observed on the object. Zero for
    /// implementations that do not track it.
    pub cas_failures: u64,
}

impl BatchStats {
    /// Accumulate another record's counters into this one.
    pub fn merge(&mut self, other: &BatchStats) {
        self.main_faas += other.main_faas;
        self.ops += other.ops;
        self.single_op_batches += other.single_op_batches;
        self.cas_failures += other.cas_failures;
    }

    pub fn avg_batch_size(&self) -> f64 {
        if self.main_faas == 0 {
            0.0
        } else {
            self.ops as f64 / self.main_faas as f64
        }
    }

    /// True iff at least one batch retired more than one operation.
    pub fn combining_occurred(&self) -> bool {
        self.ops > self.main_faas
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_folding_wraps() {
        assert_eq!(delta_to_u64(5), 5);
        assert_eq!(0u64.wrapping_add(delta_to_u64(-1)), u64::MAX);
        assert_eq!(10u64.wrapping_add(delta_to_u64(-3)), 7);
    }

    #[test]
    fn batch_stats_avg() {
        let s = BatchStats { main_faas: 4, ops: 10, ..BatchStats::default() };
        assert!((s.avg_batch_size() - 2.5).abs() < 1e-12);
        assert_eq!(BatchStats::default().avg_batch_size(), 0.0);
    }

    #[test]
    fn batch_stats_merge_covers_every_field() {
        let mut a = BatchStats { main_faas: 1, ops: 2, single_op_batches: 3, cas_failures: 4 };
        a.merge(&BatchStats { main_faas: 10, ops: 20, single_op_batches: 30, cas_failures: 40 });
        assert_eq!(a, BatchStats { main_faas: 11, ops: 22, single_op_batches: 33, cas_failures: 44 });
    }
}
