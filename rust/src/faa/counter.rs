//! The §3.1.2 counter variant: Add/Read only, no Batch objects.
//!
//! "For a counter, which supports only Add and Read operations, we can
//! save space by not using Batch objects at all — if each Aggregator
//! simply stores the value that would usually be stored in
//! `last.after`, Add operations can detect when to stop waiting for
//! their batch to be applied to Main." An `Add` has no return value,
//! so batches need no per-operation result bookkeeping: the delegate
//! bumps the Aggregator's `applied` watermark after its F&A on `Main`,
//! releasing every operation registered below the watermark.
//!
//! Allocation-free after construction — the space usage is exactly
//! Θ(m) words forever.

use std::sync::atomic::{AtomicU64, Ordering};

use super::choose::Choose;
use super::delta_to_u64;
use crate::sync::{Backoff, CachePadded};

struct CounterAggregator {
    /// Sum of magnitudes registered at this Aggregator (only grows).
    value: CachePadded<AtomicU64>,
    /// Prefix of `value` already transferred to `Main`
    /// (the role `last.after` plays in the full algorithm).
    applied: CachePadded<AtomicU64>,
}

/// A linearizable concurrent counter (Add / Read) built on the
/// Aggregating Funnels batching scheme without Batch records.
pub struct AggCounter {
    main: CachePadded<AtomicU64>,
    /// m Aggregators for positive deltas then m for negative.
    agg: Vec<CounterAggregator>,
    m: usize,
    choose: Choose,
    max_threads: usize,
}

impl AggCounter {
    pub fn new(max_threads: usize, aggregators: usize) -> Self {
        let m = aggregators.max(1);
        let agg = (0..2 * m)
            .map(|_| CounterAggregator {
                value: CachePadded::new(AtomicU64::new(0)),
                applied: CachePadded::new(AtomicU64::new(0)),
            })
            .collect();
        Self {
            main: CachePadded::new(AtomicU64::new(0)),
            agg,
            m,
            choose: Choose::StaticEven,
            max_threads: max_threads.max(1),
        }
    }

    pub fn max_threads(&self) -> usize {
        self.max_threads
    }

    /// Add `delta` to the counter (no return value — that is the whole
    /// point of the §3.1.2 simplification).
    pub fn add(&self, tid: usize, delta: i64) {
        if delta == 0 {
            return;
        }
        let positive = delta > 0;
        let g = self.choose.pick(tid, self.m, || tid as u64);
        let a = &self.agg[if positive { g } else { self.m + g }];

        let before = a.value.fetch_add(delta.unsigned_abs(), Ordering::AcqRel);
        let mut backoff = Backoff::new();
        loop {
            let applied = a.applied.load(Ordering::Acquire);
            if applied > before {
                return; // my batch reached Main
            }
            if applied == before {
                // I am the delegate: close the batch, apply it to Main,
                // then raise the watermark to release the batch.
                let after = a.value.load(Ordering::Acquire);
                let sum = after.wrapping_sub(before);
                let add = if positive { sum } else { sum.wrapping_neg() };
                self.main.fetch_add(add, Ordering::AcqRel);
                a.applied.store(after, Ordering::Release);
                return;
            }
            backoff.snooze();
        }
    }

    /// Read the counter (linearizes at the load of `Main`).
    pub fn read(&self, _tid: usize) -> u64 {
        self.main.load(Ordering::SeqCst)
    }

    /// Signed view of the counter value (for counters that stay within
    /// i64 range).
    pub fn read_signed(&self, tid: usize) -> i64 {
        self.read(tid) as i64
    }
}

// Keep the delta-folding helper linked into this module's doctests.
const _: fn(i64) -> u64 = delta_to_u64;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_adds() {
        let c = AggCounter::new(1, 2);
        c.add(0, 5);
        c.add(0, -2);
        c.add(0, 0);
        assert_eq!(c.read_signed(0), 3);
    }

    #[test]
    fn concurrent_sum_conserved() {
        let p = 8;
        let c = Arc::new(AggCounter::new(p, 2));
        let handles: Vec<_> = (0..p)
            .map(|tid| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0i64..5_000 {
                        c.add(tid, if i % 5 == 0 { -4 } else { 1 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let per_thread: i64 = (0..5_000).map(|i| if i % 5 == 0 { -4 } else { 1 }).sum();
        assert_eq!(c.read_signed(0), 8 * per_thread);
    }

    #[test]
    fn monotone_under_increments() {
        // With only positive adds, concurrent reads must be monotone.
        let c = Arc::new(AggCounter::new(4, 1));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let reader = {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut prev = 0;
                while !stop.load(Ordering::Relaxed) {
                    let v = c.read(3);
                    assert!(v >= prev, "counter went backwards: {prev} -> {v}");
                    prev = v;
                }
            })
        };
        let writers: Vec<_> = (0..3)
            .map(|tid| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..20_000 {
                        c.add(tid, 1);
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        reader.join().unwrap();
        assert_eq!(c.read(0), 60_000);
    }
}
