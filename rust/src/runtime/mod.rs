//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas
//! artifacts from the Rust request path.
//!
//! Python runs once at `make artifacts`; afterwards this module is the
//! only bridge to the compiled computations:
//!
//! * [`OracleRuntime`] — the linearization oracle
//!   (`artifacts/oracle_<N>.hlo.txt`): given a batch history it returns
//!   the expected result of every `Fetch&Add`. Histories are padded to
//!   the smallest compiled size (1024/4096/16384) with a dummy batch.
//! * [`ContentionRuntime`] — the analytic throughput model
//!   (`artifacts/contention_64.hlo.txt`) behind `aggfunnels predict`.
//!
//! The interchange format is HLO *text* (`HloModuleProto::
//! from_text_file`), not serialized protos — see DESIGN.md and
//! python/compile/aot.py for why.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Compiled oracle sizes emitted by `python/compile/aot.py`.
pub const ORACLE_SIZES: [usize; 3] = [1024, 4096, 16384];

/// Number of sweep points in the contention artifact.
pub const PREDICT_POINTS: usize = 64;

/// Locate the artifacts directory: `$AGG_ARTIFACTS`, else `artifacts/`
/// relative to the workspace root (walking up from cwd).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("AGG_ARTIFACTS") {
        return Ok(PathBuf::from(dir));
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let candidate = cur.join("artifacts");
        if candidate.join("model.hlo.txt").exists() {
            return Ok(candidate);
        }
        if !cur.pop() {
            bail!(
                "artifacts/ not found (run `make artifacts` or set AGG_ARTIFACTS)"
            );
        }
    }
}

/// A batch history in oracle layout (see python/compile/model.py).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchHistory {
    /// |delta| per op; batches contiguous, ops in linearization order.
    pub deltas: Vec<u64>,
    /// Batch index per op (nondecreasing).
    pub seg_ids: Vec<i32>,
    /// `mainBefore` per batch.
    pub seg_base: Vec<u64>,
    /// +1 / −1 per batch.
    pub seg_sign: Vec<i32>,
}

impl BatchHistory {
    pub fn ops(&self) -> usize {
        self.deltas.len()
    }

    pub fn batches(&self) -> usize {
        self.seg_base.len()
    }

    /// Append one batch; returns its segment id.
    pub fn push_batch(&mut self, main_before: u64, sign: i32, deltas: &[u64]) -> i32 {
        let seg = self.seg_base.len() as i32;
        self.seg_base.push(main_before);
        self.seg_sign.push(sign);
        for &d in deltas {
            self.deltas.push(d);
            self.seg_ids.push(seg);
        }
        seg
    }

    /// Pad to exactly `n` ops / `n` batch slots (dummy trailing batch).
    fn padded(&self, n: usize) -> Result<BatchHistory> {
        if self.ops() > n || self.batches() >= n {
            bail!("history with {} ops / {} batches exceeds oracle size {n}", self.ops(), self.batches());
        }
        let mut h = self.clone();
        let dummy_seg = h.seg_base.len() as i32;
        h.seg_base.resize(n, 0);
        h.seg_sign.resize(n, 1);
        h.deltas.resize(n, 0);
        h.seg_ids.resize(n, dummy_seg);
        Ok(h)
    }
}

/// CPU reference implementation of the oracle (used by tests and as a
/// fallback when artifacts are absent).
pub fn batch_returns_cpu(h: &BatchHistory) -> Vec<u64> {
    let mut out = Vec::with_capacity(h.deltas.len());
    let mut running: u64 = 0;
    let mut prev_seg = i32::MIN;
    for i in 0..h.deltas.len() {
        let seg = h.seg_ids[i];
        if seg != prev_seg {
            running = 0;
            prev_seg = seg;
        }
        let base = h.seg_base[seg as usize];
        out.push(if h.seg_sign[seg as usize] >= 0 {
            base.wrapping_add(running)
        } else {
            base.wrapping_sub(running)
        });
        running = running.wrapping_add(h.deltas[i]);
    }
    out
}

/// The linearization oracle, backed by PJRT executables.
pub struct OracleRuntime {
    client: xla::PjRtClient,
    /// (size, executable) pairs, ascending by size.
    exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
}

impl OracleRuntime {
    /// Load every available oracle artifact from `dir`.
    pub fn load(dir: &Path) -> Result<OracleRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = Vec::new();
        for n in ORACLE_SIZES {
            let path = dir.join(format!("oracle_{n}.hlo.txt"));
            if !path.exists() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?;
            exes.push((n, exe));
        }
        if exes.is_empty() {
            bail!("no oracle_<N>.hlo.txt artifacts in {}", dir.display());
        }
        Ok(OracleRuntime { client, exes })
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<OracleRuntime> {
        Self::load(&artifacts_dir()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn sizes(&self) -> Vec<usize> {
        self.exes.iter().map(|(n, _)| *n).collect()
    }

    /// Like [`Self::batch_returns`] but splits arbitrarily large
    /// histories into batch-aligned chunks that fit the largest
    /// compiled oracle (each batch's results are independent given its
    /// recorded `mainBefore`, so chunking is semantics-preserving).
    pub fn batch_returns_chunked(&self, history: &BatchHistory) -> Result<Vec<u64>> {
        let max = *self.exes.last().map(|(n, _)| n).unwrap_or(&0);
        if history.ops().max(history.batches() + 1) <= max {
            return self.batch_returns(history);
        }
        let mut out = Vec::with_capacity(history.ops());
        let mut chunk = BatchHistory::default();
        let mut start = 0usize;
        let flush = |chunk: &mut BatchHistory, out: &mut Vec<u64>, this: &Self| -> Result<()> {
            if chunk.ops() > 0 {
                out.extend(this.batch_returns(chunk)?);
                *chunk = BatchHistory::default();
            }
            Ok(())
        };
        for seg in 0..history.batches() {
            // ops of this batch = the contiguous seg_ids == seg range.
            let len = history.seg_ids[start..].iter().take_while(|&&s| s == seg as i32).count();
            if chunk.ops() + len > max || chunk.batches() + 2 > max {
                flush(&mut chunk, &mut out, self)?;
            }
            if len > max {
                bail!("single batch of {len} ops exceeds oracle capacity {max}");
            }
            chunk.push_batch(
                history.seg_base[seg],
                history.seg_sign[seg],
                &history.deltas[start..start + len],
            );
            start += len;
        }
        flush(&mut chunk, &mut out, self)?;
        Ok(out)
    }

    /// Expected return value of every op in `history`, computed by the
    /// AOT-compiled JAX/Pallas oracle.
    pub fn batch_returns(&self, history: &BatchHistory) -> Result<Vec<u64>> {
        let need = history.ops().max(history.batches() + 1);
        let (n, exe) = self
            .exes
            .iter()
            .find(|(n, _)| *n >= need)
            .with_context(|| format!("history too large for compiled oracles ({need} ops)"))?;
        let h = history.padded(*n)?;
        let deltas = xla::Literal::vec1(h.deltas.as_slice());
        let seg_ids = xla::Literal::vec1(h.seg_ids.as_slice());
        let seg_base = xla::Literal::vec1(h.seg_base.as_slice());
        let seg_sign = xla::Literal::vec1(h.seg_sign.as_slice());
        let result = exe
            .execute::<xla::Literal>(&[deltas, seg_ids, seg_base, seg_sign])
            .context("oracle execution")?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let mut v: Vec<u64> = out.to_vec()?;
        v.truncate(history.ops());
        Ok(v)
    }
}

/// The analytic contention model (`aggfunnels predict`).
pub struct ContentionRuntime {
    exe: xla::PjRtLoadedExecutable,
}

/// Predicted throughput curves (Mops/s).
#[derive(Clone, Debug)]
pub struct Prediction {
    pub threads: Vec<f64>,
    pub hw_mops: Vec<f64>,
    pub agg_mops: Vec<f64>,
}

impl ContentionRuntime {
    pub fn load(dir: &Path) -> Result<ContentionRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let path = dir.join(format!("contention_{PREDICT_POINTS}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(ContentionRuntime { exe })
    }

    pub fn load_default() -> Result<ContentionRuntime> {
        Self::load(&artifacts_dir()?)
    }

    /// Evaluate the model over `threads` (padded/truncated to the
    /// compiled K points).
    pub fn predict(&self, threads: &[usize], work_mean: f64, faa_ratio: f64, m: usize) -> Result<Prediction> {
        let mut p: Vec<f64> = threads.iter().map(|&t| t as f64).collect();
        p.resize(PREDICT_POINTS, *p.last().unwrap_or(&1.0));
        let p_lit = xla::Literal::vec1(p.as_slice());
        let work = xla::Literal::scalar(work_mean);
        let ratio = xla::Literal::scalar(faa_ratio);
        let m_lit = xla::Literal::scalar(m as f64);
        let result = self
            .exe
            .execute::<xla::Literal>(&[p_lit, work, ratio, m_lit])?[0][0]
            .to_literal_sync()?;
        let (hw, agg) = result.to_tuple2()?;
        let mut hw: Vec<f64> = hw.to_vec()?;
        let mut agg: Vec<f64> = agg.to_vec()?;
        hw.truncate(threads.len());
        agg.truncate(threads.len());
        Ok(Prediction {
            threads: threads.iter().map(|&t| t as f64).collect(),
            hw_mops: hw,
            agg_mops: agg,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_push_and_pad() {
        let mut h = BatchHistory::default();
        let s0 = h.push_batch(100, 1, &[5, 3]);
        let s1 = h.push_batch(108, -1, &[2]);
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(h.ops(), 3);
        assert_eq!(h.batches(), 2);
        let p = h.padded(8).unwrap();
        assert_eq!(p.deltas.len(), 8);
        assert_eq!(p.seg_ids[3..], [2, 2, 2, 2, 2]);
        assert_eq!(p.seg_base.len(), 8);
        assert!(h.padded(2).is_err());
    }

    #[test]
    fn cpu_oracle_basic() {
        let mut h = BatchHistory::default();
        h.push_batch(100, 1, &[5, 3, 2]);
        h.push_batch(50, -1, &[4, 1]);
        assert_eq!(batch_returns_cpu(&h), vec![100, 105, 108, 50, 46]);
    }

    #[test]
    fn cpu_oracle_wraps() {
        let mut h = BatchHistory::default();
        h.push_batch(u64::MAX, 1, &[2, 3]);
        assert_eq!(batch_returns_cpu(&h), vec![u64::MAX, 1]);
    }

    // PJRT-backed tests live in rust/tests/runtime_oracle.rs (they
    // need `make artifacts` to have run).
}
