//! History verification: proving runs linearizable.
//!
//! Two checkers:
//!
//! * [`verify_faa_run`] — runs a randomized concurrent workload on a
//!   *recording-mode* Aggregating Funnel, extracts the batch history
//!   (asserting Invariant 3.1 along the way), and compares every
//!   operation's recorded return value against the linearization
//!   oracle (Lemma 3.4) — either the AOT-compiled JAX/Pallas artifact
//!   through PJRT or the CPU reference. It also checks *sum
//!   conservation*: `Main` must equal the sum of all linearized
//!   deltas (Invariant 3.3).
//! * [`FifoChecker`] — validates concurrent queue runs: exact item
//!   multiset, no duplication, and per-producer order within every
//!   consumer stream (the observable consequences of FIFO
//!   linearizability without global timestamps).
//! * [`LifoChecker`] — the stack analogue for two-phase runs (all
//!   pushes complete before any pop starts): exact multiset plus
//!   per-producer *descending* order within every pop stream.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::faa::aggfunnel::{AggFunnel, AggFunnelConfig};
use crate::faa::FetchAddObject;
use crate::runtime::{batch_returns_cpu, BatchHistory, OracleRuntime};
use crate::util::rng::Rng;

/// Outcome of a verified Fetch&Add run.
#[derive(Clone, Debug)]
pub struct FaaVerifyReport {
    pub threads: usize,
    pub ops: usize,
    pub batches: usize,
    pub checked_against: &'static str,
    pub avg_batch: f64,
}

/// Which oracle backend to verify against.
pub enum OracleBackend {
    /// The AOT JAX/Pallas artifact executed through PJRT.
    Pjrt(OracleRuntime),
    /// The in-process CPU reference (always available).
    Cpu,
}

impl OracleBackend {
    fn compute(&self, h: &BatchHistory) -> Result<Vec<u64>> {
        match self {
            OracleBackend::Pjrt(rt) => rt.batch_returns_chunked(h),
            OracleBackend::Cpu => Ok(batch_returns_cpu(h)),
        }
    }

    fn label(&self) -> &'static str {
        match self {
            OracleBackend::Pjrt(_) => "pjrt-aot-oracle",
            OracleBackend::Cpu => "cpu-oracle",
        }
    }
}

/// Run `threads × ops_per_thread` random signed Fetch&Adds on a
/// recording AggFunnel and verify every return value + Invariant 3.3.
pub fn verify_faa_run(
    threads: usize,
    aggregators: usize,
    ops_per_thread: usize,
    seed: u64,
    backend: &OracleBackend,
) -> Result<FaaVerifyReport> {
    let cfg = AggFunnelConfig::new(threads).with_aggregators(aggregators).with_recording();
    let funnel = Arc::new(AggFunnel::with_config(cfg));

    let handles: Vec<_> = (0..threads)
        .map(|tid| {
            let f = Arc::clone(&funnel);
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (tid as u64).wrapping_mul(0x9E37_79B9));
                let mut sum = 0i64;
                for _ in 0..ops_per_thread {
                    // Same delta law as the paper's benches, both signs.
                    let mag = rng.range_inclusive(1, 100) as i64;
                    let delta = if rng.chance(0.5) { mag } else { -mag };
                    f.fetch_add(tid, delta);
                    sum += delta;
                }
                sum
            })
        })
        .collect();
    let expected_total: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();

    // Invariant 3.3: Main holds the sum of all linearized deltas.
    let main = funnel.read(0);
    if main != expected_total as u64 {
        bail!("sum conservation violated: Main={main}, expected {expected_total}");
    }

    let (history, recorded) = funnel.extract_history();
    let expected = backend.compute(&history)?;
    if expected.len() != recorded.len() {
        bail!("oracle returned {} values for {} ops", expected.len(), recorded.len());
    }
    for (i, (e, r)) in expected.iter().zip(recorded.iter()).enumerate() {
        if e != r {
            bail!(
                "Lemma 3.4 violated at op {i}: returned {r}, oracle says {e} \
                 (batch {})",
                history.seg_ids[i]
            );
        }
    }
    Ok(FaaVerifyReport {
        threads,
        ops: history.ops(),
        batches: history.batches(),
        checked_against: backend.label(),
        avg_batch: history.ops() as f64 / history.batches().max(1) as f64,
    })
}

/// Splits a verified history across several compiled-oracle calls —
/// exercises the PJRT padding path at every size.
pub fn verify_history_against(
    history: &BatchHistory,
    recorded: &[u64],
    backend: &OracleBackend,
) -> Result<()> {
    let expected = backend.compute(history)?;
    if expected.as_slice() != recorded {
        let idx = expected.iter().zip(recorded).position(|(a, b)| a != b).unwrap_or(0);
        bail!("mismatch at op {idx}: oracle {} vs recorded {}", expected[idx], recorded[idx]);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Queue FIFO checking
// ---------------------------------------------------------------------

/// Collects per-consumer streams of `(producer, seq)`-encoded items
/// and checks the observable FIFO properties.
#[derive(Default)]
pub struct FifoChecker {
    streams: Vec<Vec<u64>>,
}

/// Encode an item as (producer, sequence).
pub fn encode_item(producer: usize, seq: u64) -> u64 {
    ((producer as u64) << 32) | (seq & 0xFFFF_FFFF)
}

impl FifoChecker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one consumer's dequeue stream (in dequeue order).
    pub fn add_stream(&mut self, items: Vec<u64>) {
        self.streams.push(items);
    }

    /// Check against `producers × per_producer` expected items.
    pub fn check(&self, producers: usize, per_producer: u64) -> Result<()> {
        // Per-consumer: each producer's sequence must be increasing.
        for (c, stream) in self.streams.iter().enumerate() {
            let mut last = vec![None::<u64>; producers];
            for &v in stream {
                let (p, seq) = ((v >> 32) as usize, v & 0xFFFF_FFFF);
                if p >= producers {
                    bail!("consumer {c} saw item from unknown producer {p}");
                }
                if let Some(prev) = last[p] {
                    if seq <= prev {
                        bail!(
                            "FIFO violation at consumer {c}: producer {p} seq {seq} after {prev}"
                        );
                    }
                }
                last[p] = Some(seq);
            }
        }
        // Global: exact multiset.
        let mut all: Vec<u64> = self.streams.iter().flatten().copied().collect();
        let total = producers as u64 * per_producer;
        if all.len() as u64 != total {
            bail!("expected {total} items, consumed {}", all.len());
        }
        all.sort_unstable();
        all.dedup();
        if all.len() as u64 != total {
            bail!("duplicate items consumed");
        }
        for p in 0..producers as u64 {
            let count = all.iter().filter(|v| (*v >> 32) == p).count() as u64;
            if count != per_producer {
                bail!("producer {p}: {count} items consumed, expected {per_producer}");
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Stack LIFO checking
// ---------------------------------------------------------------------

/// Collects per-popper streams of `(producer, seq)`-encoded items
/// from a *two-phase* stack run — every push completes before any pop
/// starts — and checks the observable LIFO properties.
///
/// Each producer pushes its sequence numbers in increasing order, so
/// once the push phase quiesces, a producer's later items sit above
/// its earlier ones. Any single pop stream must therefore see each
/// producer's sequences in strictly *decreasing* order, and the union
/// of all streams must be the exact pushed multiset. (Interleaved
/// push/pop runs admit more orders — elimination pairs a push with a
/// concurrent pop — which is why the checker's contract is two-phase.)
#[derive(Default)]
pub struct LifoChecker {
    streams: Vec<Vec<u64>>,
}

impl LifoChecker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one popper's stream (in pop order). Items use the same
    /// [`encode_item`] packing as the FIFO checker.
    pub fn add_stream(&mut self, items: Vec<u64>) {
        self.streams.push(items);
    }

    /// Check against `producers × per_producer` expected items.
    pub fn check(&self, producers: usize, per_producer: u64) -> Result<()> {
        // Per-popper: each producer's sequence must be decreasing.
        for (c, stream) in self.streams.iter().enumerate() {
            let mut last = vec![None::<u64>; producers];
            for &v in stream {
                let (p, seq) = ((v >> 32) as usize, v & 0xFFFF_FFFF);
                if p >= producers {
                    bail!("popper {c} saw item from unknown producer {p}");
                }
                if let Some(prev) = last[p] {
                    if seq >= prev {
                        bail!(
                            "LIFO violation at popper {c}: producer {p} seq {seq} after {prev}"
                        );
                    }
                }
                last[p] = Some(seq);
            }
        }
        // Global: exact multiset.
        let mut all: Vec<u64> = self.streams.iter().flatten().copied().collect();
        let total = producers as u64 * per_producer;
        if all.len() as u64 != total {
            bail!("expected {total} items, popped {}", all.len());
        }
        all.sort_unstable();
        all.dedup();
        if all.len() as u64 != total {
            bail!("duplicate items popped");
        }
        for p in 0..producers as u64 {
            let count = all.iter().filter(|v| (*v >> 32) == p).count() as u64;
            if count != per_producer {
                bail!("producer {p}: {count} items popped, expected {per_producer}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faa_verify_against_cpu_oracle() {
        let report = verify_faa_run(4, 2, 2_000, 7, &OracleBackend::Cpu).unwrap();
        assert_eq!(report.threads, 4);
        assert_eq!(report.ops, 8_000);
        assert!(report.batches >= 1);
        assert!(report.avg_batch >= 1.0);
    }

    #[test]
    fn faa_verify_single_thread() {
        let report = verify_faa_run(1, 1, 500, 3, &OracleBackend::Cpu).unwrap();
        // Sequential: every op is its own batch.
        assert_eq!(report.batches, report.ops);
    }

    #[test]
    fn faa_verify_many_aggregators() {
        verify_faa_run(8, 6, 1_000, 11, &OracleBackend::Cpu).unwrap();
    }

    #[test]
    fn history_mismatch_detected() {
        let mut h = BatchHistory::default();
        h.push_batch(10, 1, &[1, 2]);
        let ok = vec![10u64, 11];
        verify_history_against(&h, &ok, &OracleBackend::Cpu).unwrap();
        let bad = vec![10u64, 12];
        assert!(verify_history_against(&h, &bad, &OracleBackend::Cpu).is_err());
    }

    #[test]
    fn fifo_checker_accepts_valid() {
        let mut c = FifoChecker::new();
        c.add_stream(vec![encode_item(0, 0), encode_item(1, 0), encode_item(0, 1)]);
        c.add_stream(vec![encode_item(1, 1)]);
        c.check(2, 2).unwrap();
    }

    #[test]
    fn fifo_checker_rejects_reorder() {
        let mut c = FifoChecker::new();
        c.add_stream(vec![encode_item(0, 1), encode_item(0, 0)]);
        assert!(c.check(1, 2).is_err());
    }

    #[test]
    fn fifo_checker_rejects_loss_and_dup() {
        let mut c = FifoChecker::new();
        c.add_stream(vec![encode_item(0, 0)]);
        assert!(c.check(1, 2).is_err(), "loss");
        let mut c = FifoChecker::new();
        c.add_stream(vec![encode_item(0, 0), encode_item(0, 0)]);
        assert!(c.check(1, 2).is_err(), "dup");
    }

    #[test]
    fn lifo_checker_accepts_valid() {
        let mut c = LifoChecker::new();
        // Producer 0 pushed 0,1,2; producer 1 pushed 0,1. Poppers see
        // each producer's sequences descending.
        c.add_stream(vec![encode_item(0, 2), encode_item(1, 1), encode_item(0, 1)]);
        c.add_stream(vec![encode_item(1, 0), encode_item(0, 0)]);
        c.check(2, 2).unwrap_err(); // producer 0 pushed 3 items, not 2
        let mut c = LifoChecker::new();
        c.add_stream(vec![encode_item(0, 1), encode_item(1, 1)]);
        c.add_stream(vec![encode_item(1, 0), encode_item(0, 0)]);
        c.check(2, 2).unwrap();
    }

    #[test]
    fn lifo_checker_rejects_ascending_and_dup() {
        let mut c = LifoChecker::new();
        c.add_stream(vec![encode_item(0, 0), encode_item(0, 1)]);
        assert!(c.check(1, 2).is_err(), "ascending");
        let mut c = LifoChecker::new();
        c.add_stream(vec![encode_item(0, 1), encode_item(0, 1)]);
        assert!(c.check(1, 2).is_err(), "dup");
        let mut c = LifoChecker::new();
        c.add_stream(vec![encode_item(0, 1)]);
        assert!(c.check(1, 2).is_err(), "loss");
    }

    /// The acceptance run: an elimination-backed stack stays LIFO
    /// while its elimination layer is resized under it. Two-phase
    /// (pushes quiesce before pops start), with a resizer thread
    /// churning the active width through both phases.
    #[test]
    fn elimination_stack_lifo_under_concurrent_resize() {
        use std::sync::atomic::{AtomicBool, Ordering};

        const PRODUCERS: usize = 4;
        const POPPERS: usize = 4;
        const PER_PRODUCER: u64 = 2_000;

        let stack = crate::queue::stack::make_stack("stack+elastic", PRODUCERS + POPPERS, None)
            .expect("stack+elastic spec");
        let stop = Arc::new(AtomicBool::new(false));
        let resizer = {
            let (stack, stop) = (Arc::clone(&stack), Arc::clone(&stop));
            std::thread::spawn(move || {
                let mut width = 1usize;
                while !stop.load(Ordering::Relaxed) {
                    stack.resize_elimination(width);
                    width = if width >= 8 { 1 } else { width * 2 };
                    std::thread::yield_now();
                }
            })
        };

        // Phase 1: concurrent pushes.
        let pushers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let stack = Arc::clone(&stack);
                std::thread::spawn(move || {
                    for seq in 0..PER_PRODUCER {
                        stack.push(p, encode_item(p, seq));
                    }
                })
            })
            .collect();
        pushers.into_iter().for_each(|h| h.join().unwrap());

        // Phase 2: concurrent pops drain it dry.
        let poppers: Vec<_> = (0..POPPERS)
            .map(|c| {
                let stack = Arc::clone(&stack);
                std::thread::spawn(move || {
                    let tid = PRODUCERS + c;
                    let mut stream = Vec::new();
                    while let Some(v) = stack.pop(tid) {
                        stream.push(v);
                    }
                    stream
                })
            })
            .collect();
        let mut checker = LifoChecker::new();
        for h in poppers {
            checker.add_stream(h.join().unwrap());
        }
        stop.store(true, Ordering::Relaxed);
        resizer.join().unwrap();

        checker.check(PRODUCERS, PER_PRODUCER).unwrap();
        assert_eq!(stack.pop(0), None, "drained");
    }
}
