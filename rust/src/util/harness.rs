//! Micro-benchmark timing harness (criterion is not available offline).
//!
//! `Bencher` runs a closure repeatedly with warmup, adaptively sizing
//! batches so each measurement batch lasts ~`batch_target`; it reports
//! mean/median/p95 per-iteration time and iterations/second. The
//! `benches/*.rs` targets (declared with `harness = false`) and the
//! figure drivers are built on this.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one benchmark: per-iteration nanoseconds statistics.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter: Summary,
}

impl BenchResult {
    pub fn ops_per_sec(&self) -> f64 {
        if self.ns_per_iter.mean <= 0.0 {
            0.0
        } else {
            1e9 / self.ns_per_iter.mean
        }
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.1} ns/iter (p50 {:>10.1}, p95 {:>10.1})  {:>12.0} iters/s",
            self.name, self.ns_per_iter.mean, self.ns_per_iter.p50, self.ns_per_iter.p95,
            self.ops_per_sec()
        )
    }
}

/// Adaptive micro-benchmark runner.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub batch_target: Duration,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            batch_target: Duration::from_millis(10),
            samples: 32,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            batch_target: Duration::from_millis(2),
            samples: 16,
        }
    }

    /// Benchmark `f`, which performs exactly one "iteration" per call.
    pub fn bench(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        // Warmup + estimate cost of one iteration.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 8 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000_000 {
                break;
            }
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / warm_iters as f64).max(0.5);
        let batch = ((self.batch_target.as_nanos() as f64 / est_ns) as u64).clamp(1, 1 << 24);

        let mut per_iter_ns = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        let measure_start = Instant::now();
        while per_iter_ns.len() < self.samples && measure_start.elapsed() < self.measure * 4 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / batch as f64;
            per_iter_ns.push(ns);
            total_iters += batch;
            if measure_start.elapsed() >= self.measure && per_iter_ns.len() >= 8 {
                break;
            }
        }
        BenchResult {
            name: name.to_string(),
            iters: total_iters,
            ns_per_iter: Summary::of(&per_iter_ns),
        }
    }

    /// Benchmark with per-batch setup: `setup` produces state consumed
    /// by one timed call of `f`.
    pub fn bench_with_setup<S>(
        &self,
        name: &str,
        mut setup: impl FnMut() -> S,
        mut f: impl FnMut(S),
    ) -> BenchResult {
        let mut samples = Vec::with_capacity(self.samples);
        // Warmup.
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            let s = setup();
            f(s);
        }
        let mut total = 0u64;
        for _ in 0..self.samples {
            let s = setup();
            let t = Instant::now();
            f(s);
            samples.push(t.elapsed().as_nanos() as f64);
            total += 1;
        }
        BenchResult { name: name.to_string(), iters: total, ns_per_iter: Summary::of(&samples) }
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept behind one name so call sites read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters > 0);
        assert!(r.ns_per_iter.mean > 0.0);
        assert!(r.ns_per_iter.mean < 1e6, "a no-op should not take 1ms");
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn bench_with_setup_runs() {
        let b = Bencher::quick();
        let r = b.bench_with_setup(
            "sum-vec",
            || (0..1000u64).collect::<Vec<_>>(),
            |v| {
                black_box(v.iter().sum::<u64>());
            },
        );
        assert_eq!(r.iters, b.samples as u64);
    }
}
