//! A small hand-rolled command-line parser (no external deps).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, and generates usage text from declared options. This is
//! the substrate behind the `aggfunnels` binary's subcommands and the
//! per-figure bench drivers.

use std::collections::BTreeMap;

/// A declared option, used for parsing and for `--help` output.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed command line: option values plus positional arguments.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.parse_as(name).unwrap_or(default)
    }
}

/// Command parser: declared options + free-form positionals.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

/// Error produced on unknown or malformed arguments.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self { program, about, opts: Vec::new() }
    }

    /// Declare an option that takes a value (`--name V` or `--name=V`).
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    /// Declare a boolean flag (`--name`).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let dashes = format!("--{}", o.name);
            let arg = if o.takes_value { " <value>" } else { "" };
            let def = match o.default {
                Some(d) => format!(" [default: {d}]"),
                None => String::new(),
            };
            s.push_str(&format!("  {dashes}{arg:<10} {}{}\n", o.help, def));
        }
        s.push_str("  --help       print this message\n");
        s
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Parse an argument vector (excluding argv[0]).
    pub fn parse<I, S>(&self, args: I) -> Result<Parsed, CliError>
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut parsed = Parsed::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                parsed.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let args: Vec<String> = args.into_iter().map(|s| s.as_ref().to_string()).collect();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(body) = a.strip_prefix("--") {
                if body == "help" {
                    return Err(CliError(self.usage()));
                }
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .spec(name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}\n\n{}", self.usage())))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("option --{name} needs a value")))?
                        }
                    };
                    parsed.values.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{name} does not take a value")));
                    }
                    parsed.flags.push(name.to_string());
                }
            } else {
                parsed.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }

    /// Parse `std::env::args()` (skipping argv[0]); on error print and exit.
    pub fn parse_env(&self) -> Parsed {
        match self.parse(std::env::args().skip(1)) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("threads", Some("4"), "thread count")
            .opt("algo", None, "algorithm")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(Vec::<&str>::new()).unwrap();
        assert_eq!(p.get("threads"), Some("4"));
        assert_eq!(p.get("algo"), None);
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = cli().parse(["--threads", "8", "--algo=agg"]).unwrap();
        assert_eq!(p.parse_as::<usize>("threads"), Some(8));
        assert_eq!(p.get("algo"), Some("agg"));
    }

    #[test]
    fn flags_and_positionals() {
        let p = cli().parse(["--verbose", "pos1", "pos2"]).unwrap();
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse(["--nope"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(["--algo"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cli().parse(["--verbose=1"]).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cli().parse(["--help"]).unwrap_err();
        assert!(err.0.contains("thread count"));
    }

    #[test]
    fn parse_or_fallback() {
        let p = cli().parse(["--threads", "junk"]).unwrap();
        assert_eq!(p.parse_or::<usize>("threads", 3), 3);
    }
}
