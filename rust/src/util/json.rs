//! Minimal JSON value model with a serializer and a recursive-descent
//! parser. Used for machine-readable benchmark/metrics output and for
//! the ticket service's wire protocol.
//!
//! Scope: full JSON except that numbers are kept as `f64` (with an
//! integer fast path on serialization) — sufficient for our payloads.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize compactly into a caller-owned buffer (appends) — the
    /// allocation-free twin of [`Json::to_string`] for hot paths that
    /// reuse one scratch `String` across many replies.
    pub fn write_into(&self, out: &mut String) {
        self.write(out);
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = Json::obj(vec![
            ("a", Json::num(1.0)),
            ("b", Json::str("hi")),
            ("c", Json::arr([Json::Bool(true), Json::Null])),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn parse_strings_with_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn serialize_escapes() {
        let s = Json::str("x\"y\\z\n").to_string();
        assert_eq!(s, r#""x\"y\\z\n""#);
    }

    #[test]
    fn nested_structures() {
        let src = r#"{"outer": {"inner": [1, 2, {"k": null}]}, "n": 3}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(3));
        let inner = v.get("outer").unwrap().get("inner").unwrap().as_arr().unwrap();
        assert_eq!(inner.len(), 3);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }
}
