//! A TOML-subset parser for configuration files.
//!
//! Supported: `[table]` and `[table.subtable]` headers, `key = value`
//! with string/integer/float/boolean/array values, `#` comments, and
//! dotted keys in headers. This covers everything `configs/*.toml`
//! uses; unsupported TOML (multi-line strings, inline tables, dates)
//! is rejected with a line-numbered error.

use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A flat document: keys are dotted paths (`table.sub.key`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Merge `other` on top of `self` (other's keys win).
    pub fn merge_from(&mut self, other: TomlDoc) {
        for (k, v) in other.entries {
            self.entries.insert(k, v);
        }
    }

    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let inner = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?
                    .trim();
                if inner.is_empty() || inner.starts_with('[') {
                    return Err(format!("line {}: unsupported table header", lineno + 1));
                }
                validate_key_path(inner).map_err(|e| format!("line {}: {e}", lineno + 1))?;
                prefix = format!("{inner}.");
            } else if let Some((key, val)) = line.split_once('=') {
                let key = key.trim();
                validate_key_path(key).map_err(|e| format!("line {}: {e}", lineno + 1))?;
                let value = parse_value(val.trim())
                    .map_err(|e| format!("line {}: {e}", lineno + 1))?;
                doc.entries.insert(format!("{prefix}{key}"), value);
            } else {
                return Err(format!("line {}: expected `key = value` or `[table]`", lineno + 1));
            }
        }
        Ok(doc)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<TomlDoc, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        TomlDoc::parse(&text)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` starts a comment unless inside a string literal.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn validate_key_path(path: &str) -> Result<(), String> {
    for seg in path.split('.') {
        if seg.is_empty()
            || !seg.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(format!("invalid key {path:?}"));
        }
    }
    Ok(())
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        if body.contains('"') {
            return Err("embedded quote in string (escapes unsupported)".into());
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?.trim();
        let mut items = Vec::new();
        if !body.is_empty() {
            for item in split_top_level(body) {
                items.push(parse_value(item.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    let cleaned: String = s.chars().filter(|c| *c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas that are not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = TomlDoc::parse(
            r#"
            # comment
            name = "bench"   # trailing comment
            threads = 176
            ratio = 0.9
            enabled = true

            [sim]
            sockets = 4
            costs = [4, 70, 140]

            [sim.smt]
            ways = 2
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "bench");
        assert_eq!(doc.int_or("threads", 0), 176);
        assert!((doc.float_or("ratio", 0.0) - 0.9).abs() < 1e-12);
        assert!(doc.bool_or("enabled", false));
        assert_eq!(doc.int_or("sim.sockets", 0), 4);
        assert_eq!(doc.int_or("sim.smt.ways", 0), 2);
        let arr = doc.get("sim.costs").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_int(), Some(70));
    }

    #[test]
    fn int_with_underscores() {
        let doc = TomlDoc::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.int_or("big", 0), 1_000_000);
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("m = [[1, 2], [3]]").unwrap();
        let outer = doc.get("m").unwrap().as_array().unwrap();
        assert_eq!(outer[0].as_array().unwrap().len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0].as_int(), Some(3));
    }

    #[test]
    fn merge_wins() {
        let mut a = TomlDoc::parse("x = 1\ny = 2").unwrap();
        let b = TomlDoc::parse("y = 3\nz = 4").unwrap();
        a.merge_from(b);
        assert_eq!(a.int_or("x", 0), 1);
        assert_eq!(a.int_or("y", 0), 3);
        assert_eq!(a.int_or("z", 0), 4);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_bad_values() {
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"unterminated").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("[bad key]").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn float_values() {
        let doc = TomlDoc::parse("f = -2.5e3").unwrap();
        assert_eq!(doc.float_or("f", 0.0), -2500.0);
    }
}
