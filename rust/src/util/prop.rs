//! Randomized property testing (proptest-style, hand-rolled).
//!
//! `props::run` executes a property over many random cases from a
//! seeded generator; on failure it retries with a simple input-size
//! shrink schedule and reports the seed so the case can be replayed
//! deterministically. Used throughout the test suite for invariant
//! checks (linearizability, batch-list structure, queue FIFO, parser
//! round-trips).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (e.g. collection
    /// length); the runner sweeps sizes from small to large so early
    /// failures are already small.
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        // `AGG_PROP_CASES` / `AGG_PROP_SEED` allow CI to crank or pin runs.
        let cases = std::env::var("AGG_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64);
        let seed = std::env::var("AGG_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA66F_0000_D00D_5EED);
        Self { cases, seed, max_size: 64 }
    }
}

/// A single generated case: RNG plus a size hint.
pub struct Case<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
    pub index: usize,
}

impl Case<'_> {
    /// Vector of length `0..=size` with elements from `g`.
    pub fn vec_of<T>(&mut self, mut g: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let len = self.rng.below(self.size as u64 + 1) as usize;
        (0..len).map(|_| g(self.rng)).collect()
    }

    /// Non-empty vector of length `1..=max(size,1)`.
    pub fn nonempty_vec_of<T>(&mut self, mut g: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let len = self.rng.range_inclusive(1, self.size.max(1) as u64) as usize;
        (0..len).map(|_| g(self.rng)).collect()
    }
}

/// Run `prop` over `cfg.cases` random cases; panic with replay info on
/// the first failure. The property returns `Err(reason)` to fail.
pub fn run(name: &str, cfg: PropConfig, mut prop: impl FnMut(&mut Case) -> Result<(), String>) {
    let mut rng = Rng::new(cfg.seed);
    for index in 0..cfg.cases {
        // Size ramps from 1 to max_size across the run.
        let size = 1 + (cfg.max_size.saturating_sub(1)) * index / cfg.cases.max(1);
        let mut case_rng = rng.fork(index as u64);
        let mut case = Case { rng: &mut case_rng, size, index };
        if let Err(reason) = prop(&mut case) {
            panic!(
                "property {name:?} failed on case {index} (size {size}, seed {:#x}):\n  {reason}\n\
                 replay with AGG_PROP_SEED={} AGG_PROP_CASES={}",
                cfg.seed,
                cfg.seed,
                index + 1,
            );
        }
    }
}

/// Shorthand: run with default config.
pub fn check(name: &str, prop: impl FnMut(&mut Case) -> Result<(), String>) {
    run(name, PropConfig::default(), prop)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {}  ({a:?} vs {b:?})",
                stringify!($a),
                stringify!($b)
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run("count", PropConfig { cases: 10, seed: 1, max_size: 8 }, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property \"fails\"")]
    fn failing_property_panics_with_replay_info() {
        run("fails", PropConfig { cases: 4, seed: 2, max_size: 4 }, |c| {
            if c.index == 2 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn vec_generators_respect_size() {
        run("sizes", PropConfig { cases: 32, seed: 3, max_size: 16 }, |c| {
            let size = c.size;
            let v = c.vec_of(|r| r.next_u64());
            prop_assert!(v.len() <= size, "len {} > size {}", v.len(), size);
            let nv = c.nonempty_vec_of(|r| r.next_u64());
            prop_assert!(!nv.is_empty(), "nonempty_vec_of produced empty");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = |seed| {
            let mut vals = Vec::new();
            run("det", PropConfig { cases: 5, seed, max_size: 8 }, |c| {
                vals.push(c.rng.next_u64());
                Ok(())
            });
            vals
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }
}
