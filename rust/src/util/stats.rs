//! Small statistics helpers used by the benchmark harness: mean,
//! stddev, percentiles, min/max ratios (the paper's fairness metric)
//! and throughput formatting.

/// Summary statistics over a sample of `f64` observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0)
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Percentile by linear interpolation over a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// The paper's fairness metric (§4.1): ratio between the minimum and
/// maximum number of operations completed by any thread. 1.0 is
/// perfectly fair; values near 0 indicate starved threads.
pub fn fairness(per_thread_ops: &[u64]) -> f64 {
    if per_thread_ops.is_empty() {
        return 1.0;
    }
    let min = *per_thread_ops.iter().min().unwrap();
    let max = *per_thread_ops.iter().max().unwrap();
    if max == 0 {
        1.0
    } else {
        min as f64 / max as f64
    }
}

/// Jain's fairness index — a secondary fairness measure we report in
/// the extended benchmarks: `(Σx)² / (n · Σx²)`, in `(0, 1]`.
pub fn jain_index(per_thread_ops: &[u64]) -> f64 {
    if per_thread_ops.is_empty() {
        return 1.0;
    }
    let sum: f64 = per_thread_ops.iter().map(|&x| x as f64).sum();
    let sumsq: f64 = per_thread_ops.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if sumsq == 0.0 {
        1.0
    } else {
        sum * sum / (per_thread_ops.len() as f64 * sumsq)
    }
}

/// Format ops/second as `Mops/s` with 3 significant decimals, matching
/// how the paper reports throughput.
pub fn mops(ops: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    ops as f64 / seconds / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn fairness_metric() {
        assert_eq!(fairness(&[10, 10, 10]), 1.0);
        assert_eq!(fairness(&[5, 10]), 0.5);
        assert_eq!(fairness(&[0, 10]), 0.0);
        assert_eq!(fairness(&[]), 1.0);
        assert_eq!(fairness(&[0, 0]), 1.0);
    }

    #[test]
    fn jain_bounds() {
        assert_eq!(jain_index(&[5, 5, 5, 5]), 1.0);
        let j = jain_index(&[1, 0, 0, 0]);
        assert!((j - 0.25).abs() < 1e-12);
    }

    #[test]
    fn mops_formatting() {
        assert!((mops(2_000_000, 2.0) - 1.0).abs() < 1e-12);
        assert_eq!(mops(10, 0.0), 0.0);
    }
}
