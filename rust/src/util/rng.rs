//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` for seeding, `Xoshiro256StarStar` as the workhorse
//! generator (Blackman & Vigna). Both are tiny, allocation-free and
//! reproducible across platforms, which the simulator and the
//! property-testing helper rely on.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single word.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = sm.next_u64();
        }
        // xoshiro must not be seeded with all zeros.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    /// Derive an independent stream (for per-thread generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift; bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Geometric sample with the given mean (support `{0, 1, 2, ...}`).
    ///
    /// This matches the paper's workload model of "a geometrically
    /// distributed random amount of additional local work" with a given
    /// mean number of cycles. Sampled by inversion of the exponential.
    #[inline]
    pub fn geometric(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        // p = 1/(mean+1); X = floor(ln(U)/ln(1-p)) has mean `mean`.
        let p = 1.0 / (mean + 1.0);
        let u = 1.0 - self.f64(); // (0, 1]
        let x = u.ln() / (1.0 - p).ln();
        if x >= u64::MAX as f64 {
            u64::MAX
        } else {
            x as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
        for _ in 0..1000 {
            let v = r.range_inclusive(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn geometric_mean_roughly_right() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean = 512.0;
        let sum: u64 = (0..n).map(|_| r.geometric(mean)).sum();
        let emp = sum as f64 / n as f64;
        assert!(
            (emp - mean).abs() < mean * 0.05,
            "empirical mean {emp} too far from {mean}"
        );
    }

    #[test]
    fn geometric_zero_mean() {
        let mut r = Rng::new(1);
        assert_eq!(r.geometric(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
