//! Hand-rolled general-purpose substrates.
//!
//! The build is fully offline (only `xla` + `anyhow` are vendored), so
//! the crate carries its own PRNG, stats, CLI parser, TOML-subset
//! config reader, JSON emitter, micro-benchmark timing harness and a
//! proptest-style randomized property-testing helper.

pub mod cli;
pub mod harness;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tomlmini;

/// Number of bytes in a cache line on every machine we care about.
pub const CACHE_LINE: usize = 64;

/// Create a unique scratch directory under the system temp dir (no
/// external tempfile crate): pid + wall-clock nanos keep concurrent
/// test binaries and benchmark points apart. The caller owns cleanup.
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let dir = std::env::temp_dir()
        .join(format!("aggfunnels-{tag}-{}-{nanos}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("creating scratch dir");
    dir
}

/// Parse a human-friendly count like `"4k"`, `"2m"`, `"1g"` or `"1000"`.
pub fn parse_count(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.as_bytes()[s.len() - 1].to_ascii_lowercase() {
        b'k' => (&s[..s.len() - 1], 1_000u64),
        b'm' => (&s[..s.len() - 1], 1_000_000u64),
        b'g' => (&s[..s.len() - 1], 1_000_000_000u64),
        _ => (s, 1u64),
    };
    num.trim().parse::<u64>().ok().map(|n| n * mult)
}

/// Parse a comma-separated list of integers with optional ranges, e.g.
/// `"1,2,4:8,16"` (`a:b` is inclusive).
pub fn parse_int_list(s: &str) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once(':') {
            let a: usize = a.trim().parse().ok()?;
            let b: usize = b.trim().parse().ok()?;
            if a > b {
                return None;
            }
            out.extend(a..=b);
        } else {
            out.push(part.parse().ok()?);
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_count_plain() {
        assert_eq!(parse_count("1000"), Some(1000));
    }

    #[test]
    fn parse_count_suffixes() {
        assert_eq!(parse_count("4k"), Some(4_000));
        assert_eq!(parse_count("2M"), Some(2_000_000));
        assert_eq!(parse_count("1g"), Some(1_000_000_000));
    }

    #[test]
    fn parse_count_garbage() {
        assert_eq!(parse_count(""), None);
        assert_eq!(parse_count("x"), None);
        assert_eq!(parse_count("12q"), None);
    }

    #[test]
    fn parse_int_list_ranges() {
        assert_eq!(parse_int_list("1,2,4:6"), Some(vec![1, 2, 4, 5, 6]));
        assert_eq!(parse_int_list("7"), Some(vec![7]));
        assert_eq!(parse_int_list("3:1"), None);
    }
}
