//! Application configuration: TOML-subset files merged with CLI
//! overrides.
//!
//! Resolution order (later wins): built-in defaults → `--config
//! <file>` → individual CLI flags. `configs/default.toml` documents
//! every key.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::sim::{CacheCosts, SimConfig};
use crate::util::tomlmini::TomlDoc;

/// Simulator settings (maps onto [`SimConfig`]).
#[derive(Clone, Debug, PartialEq)]
pub struct SimSettings {
    pub sockets: usize,
    pub cpus_per_socket: usize,
    pub freq_ghz: f64,
    pub local: u64,
    pub same_socket: u64,
    pub cross_socket: u64,
    pub wake: u64,
    pub owner_sticky: bool,
    pub horizon_cycles: u64,
    pub seed: u64,
}

impl Default for SimSettings {
    fn default() -> Self {
        let c = SimConfig::c3_standard_176(1);
        Self {
            sockets: c.sockets,
            cpus_per_socket: c.cpus_per_socket,
            freq_ghz: c.freq_ghz,
            local: c.costs.local,
            same_socket: c.costs.same_socket,
            cross_socket: c.costs.cross_socket,
            wake: c.costs.wake,
            owner_sticky: c.costs.owner_sticky,
            horizon_cycles: 3_000_000,
            seed: 0xF16_5EED,
        }
    }
}

impl SimSettings {
    pub fn to_sim_config(&self, threads: usize) -> SimConfig {
        SimConfig {
            threads,
            sockets: self.sockets,
            cpus_per_socket: self.cpus_per_socket,
            freq_ghz: self.freq_ghz,
            costs: CacheCosts {
                local: self.local,
                same_socket: self.same_socket,
                cross_socket: self.cross_socket,
                wake: self.wake,
                owner_sticky: self.owner_sticky,
            },
            horizon_cycles: self.horizon_cycles,
            seed: self.seed,
        }
    }
}

/// Benchmark settings.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchSettings {
    /// Thread grid for sweeps.
    pub grid: Vec<usize>,
    /// Output directory for TSV results.
    pub out_dir: String,
    /// Native measurement duration per point, milliseconds.
    pub native_ms: u64,
    /// Default Aggregator count (the paper's m = 6).
    pub aggregators: usize,
}

impl Default for BenchSettings {
    fn default() -> Self {
        Self {
            grid: vec![1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 176],
            out_dir: "results".into(),
            native_ms: 500,
            aggregators: 6,
        }
    }
}

/// One named object pre-created at service boot, from an
/// `[objects.<name>]` manifest section:
///
/// ```toml
/// [objects.orders]
/// kind = "counter"            # default kind
/// backend = "elastic:aimd"    # default counter backend
/// direct_quota = 2            # §4.4 d: max concurrent Fetch&AddDirect
///
/// [objects.jobs]
/// kind = "queue"
/// backend = "lcrq+elastic"    # default queue backend
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ObjectManifest {
    pub name: String,
    /// `"counter"` or `"queue"`.
    pub kind: String,
    /// Backend spec — counters use the [`crate::faa::BackendSpec`]
    /// grammar, queues the [`crate::queue::make_queue`] grammar.
    pub backend: String,
    /// §4.4 direct-thread quota `d` for counters (`None` = unlimited
    /// direct; every `priority` request bypasses the funnel).
    pub direct_quota: Option<usize>,
    /// Durability opt-out: `persist = false` keeps this object
    /// ephemeral even when the service runs with a `data_dir`
    /// (re-created fresh from the manifest at every boot).
    pub persist: bool,
}

impl ObjectManifest {
    /// A quota-less manifest (the common case and the PR 3 shape).
    pub fn new(name: impl Into<String>, kind: impl Into<String>, backend: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            kind: kind.into(),
            backend: backend.into(),
            direct_quota: None,
            persist: true,
        }
    }
    /// The backend spec an object kind defaults to when none is given
    /// (used for kind validation here and for defaulting at object
    /// creation); `None` for unknown kinds.
    pub fn default_backend(kind: &str) -> Option<&'static str> {
        match kind {
            "counter" => Some("elastic:aimd"),
            "queue" => Some("lcrq+elastic"),
            "stack" => Some("stack+elastic"),
            _ => None,
        }
    }
}

/// Ticket-service settings.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceSettings {
    pub addr: String,
    /// Number of independent registry shards. Shard `i` listens on
    /// `addr`'s port + `i` (each shard picks its own ephemeral port
    /// when the configured port is 0); object names route to shards
    /// by FNV-1a hash. `1` (the default) is wire-compatible with the
    /// pre-shard protocol.
    pub shards: usize,
    /// Maximum concurrent client connections *per shard* (each
    /// shard's tid lease pool).
    pub workers: usize,
    pub aggregators: usize,
    /// Width policy for the elastic funnel: `fixed:<m>` (or a bare
    /// integer), `sqrtp`, or `aimd`.
    pub width_policy: String,
    /// Aggregator slot capacity per sign (the elastic ceiling).
    pub max_aggregators: usize,
    /// Controller poll period for adaptive policies, in milliseconds
    /// (0 disables the resize controller thread).
    pub resize_interval_ms: u64,
    /// Default CAS retry policy for hot-loop contention management:
    /// `none`, `const`, `exp`, or `adaptive`. Objects created with a
    /// `:b<policy>` backend-spec suffix override it per object.
    pub cas_policy: String,
    /// Durability root: each shard persists a WAL + snapshots under
    /// `<data_dir>/shard-<i>` and recovers from them at boot. Empty
    /// (the default) disables persistence entirely.
    pub data_dir: String,
    /// Master durability switch: `false` ignores `data_dir` (useful
    /// to boot a config with persistence temporarily off).
    pub persist: bool,
    /// Group-commit interval in milliseconds (one WAL append per
    /// object per interval); `0` = synchronous mode — every mutation
    /// appends its record before the response is acked.
    pub fsync_interval_ms: u64,
    /// Snapshot rewrite period in milliseconds (`0` = only at boot,
    /// graceful shutdown, and the `snapshot` wire op).
    pub snapshot_interval_ms: u64,
    /// Poll-loop threads per shard (accepted connections fan out to
    /// the least-loaded poller).
    pub io_threads: usize,
    /// Maximum open connections per shard; over-limit connects get an
    /// `at_capacity` reply and a clean close.
    pub max_conns: usize,
    /// Backpressure ceiling: decoded-but-undrained requests per shard
    /// before the poll loop stops reading sockets (TCP pushback).
    pub max_pending: usize,
    /// Merge same-object same-kind request runs inside each executor
    /// sweep into single funnel batches (`false` = the one-op-at-a-
    /// time baseline, kept for A/B measurement).
    pub coalesce: bool,
    /// Fairness cap: requests one executor sweep drains from a single
    /// connection before moving on (leftovers re-schedule it).
    pub max_ops_per_sweep: usize,
    /// Objects pre-created at boot (besides the default counter).
    pub objects: Vec<ObjectManifest>,
}

impl Default for ServiceSettings {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7471".into(),
            shards: 1,
            workers: 8,
            aggregators: 6,
            width_policy: "aimd".into(),
            max_aggregators: 12,
            resize_interval_ms: 25,
            cas_policy: "adaptive".into(),
            data_dir: String::new(),
            persist: true,
            fsync_interval_ms: 5,
            snapshot_interval_ms: 60_000,
            io_threads: 1,
            max_conns: 1024,
            max_pending: 4096,
            coalesce: true,
            max_ops_per_sweep: 128,
            objects: Vec::new(),
        }
    }
}

/// Root application configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AppConfig {
    pub sim: SimSettings,
    pub bench: BenchSettings,
    pub service: ServiceSettings,
}

impl AppConfig {
    /// Apply a parsed TOML document on top of `self`.
    pub fn apply_doc(&mut self, doc: &TomlDoc) -> Result<()> {
        let s = &mut self.sim;
        s.sockets = doc.int_or("sim.sockets", s.sockets as i64) as usize;
        s.cpus_per_socket = doc.int_or("sim.cpus_per_socket", s.cpus_per_socket as i64) as usize;
        s.freq_ghz = doc.float_or("sim.freq_ghz", s.freq_ghz);
        s.local = doc.int_or("sim.costs.local", s.local as i64) as u64;
        s.same_socket = doc.int_or("sim.costs.same_socket", s.same_socket as i64) as u64;
        s.cross_socket = doc.int_or("sim.costs.cross_socket", s.cross_socket as i64) as u64;
        s.wake = doc.int_or("sim.costs.wake", s.wake as i64) as u64;
        s.owner_sticky = doc.bool_or("sim.costs.owner_sticky", s.owner_sticky);
        s.horizon_cycles = doc.int_or("sim.horizon_cycles", s.horizon_cycles as i64) as u64;
        s.seed = doc.int_or("sim.seed", s.seed as i64) as u64;

        let b = &mut self.bench;
        if let Some(v) = doc.get("bench.grid") {
            let arr = v
                .as_array()
                .ok_or_else(|| anyhow!("bench.grid must be an array of integers"))?;
            b.grid = arr
                .iter()
                .map(|x| x.as_int().map(|i| i as usize))
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| anyhow!("bench.grid must contain integers"))?;
        }
        b.out_dir = doc.str_or("bench.out_dir", &b.out_dir);
        b.native_ms = doc.int_or("bench.native_ms", b.native_ms as i64) as u64;
        b.aggregators = doc.int_or("bench.aggregators", b.aggregators as i64) as usize;

        let sv = &mut self.service;
        sv.addr = doc.str_or("service.addr", &sv.addr);
        // Clamp on the i64 before the cast: a negative value must
        // floor to 1, not wrap to a huge count (the service sizes
        // funnel thread tables from `workers`).
        sv.shards = doc.int_or("service.shards", sv.shards as i64).max(1) as usize;
        sv.workers = doc.int_or("service.workers", sv.workers as i64).max(1) as usize;
        sv.aggregators =
            doc.int_or("service.aggregators", sv.aggregators as i64).max(1) as usize;
        sv.width_policy = doc.str_or("service.width_policy", &sv.width_policy);
        sv.max_aggregators =
            doc.int_or("service.max_aggregators", sv.max_aggregators as i64).max(1) as usize;
        sv.resize_interval_ms =
            doc.int_or("service.resize_interval_ms", sv.resize_interval_ms as i64).max(0) as u64;
        sv.cas_policy = doc.str_or("service.cas_policy", &sv.cas_policy);
        if crate::sync::RetryPolicy::parse(&sv.cas_policy).is_none() {
            return Err(anyhow!(
                "service.cas_policy must be none | const | exp | adaptive, got {:?}",
                sv.cas_policy
            ));
        }
        sv.data_dir = doc.str_or("service.data_dir", &sv.data_dir);
        sv.persist = doc.bool_or("service.persist", sv.persist);
        sv.fsync_interval_ms =
            doc.int_or("service.fsync_interval_ms", sv.fsync_interval_ms as i64).max(0) as u64;
        sv.snapshot_interval_ms = doc
            .int_or("service.snapshot_interval_ms", sv.snapshot_interval_ms as i64)
            .max(0) as u64;
        if doc.get("service.conn_mode").is_some() {
            return Err(anyhow!(
                "service.conn_mode was removed: the event core is the only connection core"
            ));
        }
        sv.io_threads = doc.int_or("service.io_threads", sv.io_threads as i64).max(1) as usize;
        sv.max_conns = doc.int_or("service.max_conns", sv.max_conns as i64).max(1) as usize;
        sv.max_pending =
            doc.int_or("service.max_pending", sv.max_pending as i64).max(1) as usize;
        sv.coalesce = doc.bool_or("service.coalesce", sv.coalesce);
        sv.max_ops_per_sweep =
            doc.int_or("service.max_ops_per_sweep", sv.max_ops_per_sweep as i64).max(1) as usize;

        // `[objects.<name>]` manifest sections; later layers override
        // per name, fields merge within a name.
        let mut objects: std::collections::BTreeMap<String, ObjectManifest> =
            sv.objects.iter().map(|o| (o.name.clone(), o.clone())).collect();
        for (key, value) in &doc.entries {
            let Some(rest) = key.strip_prefix("objects.") else { continue };
            let (name, field) = rest.split_once('.').ok_or_else(|| {
                anyhow!("object manifests need `objects.<name>.<field>`, got {key:?}")
            })?;
            let entry = objects
                .entry(name.to_string())
                .or_insert_with(|| ObjectManifest::new(name, "counter", ""));
            match field {
                "kind" => {
                    entry.kind = value
                        .as_str()
                        .ok_or_else(|| anyhow!("{key}: kind must be a string"))?
                        .to_string();
                }
                "backend" => {
                    entry.backend = value
                        .as_str()
                        .ok_or_else(|| anyhow!("{key}: backend must be a string"))?
                        .to_string();
                }
                "direct_quota" => {
                    // Accept an integer or an integer-valued string.
                    let d = value
                        .as_int()
                        .or_else(|| value.as_str().and_then(|s| s.trim().parse().ok()))
                        .filter(|d| *d >= 0)
                        .ok_or_else(|| {
                            anyhow!("{key}: direct_quota must be a non-negative integer")
                        })?;
                    entry.direct_quota = Some(d as usize);
                }
                "persist" => {
                    entry.persist = value
                        .as_bool()
                        .ok_or_else(|| anyhow!("{key}: persist must be a boolean"))?;
                }
                other => return Err(anyhow!("unknown object field {other:?} in {key:?}")),
            }
        }
        for o in objects.values() {
            // Validate the kind early (clear config-time error), but
            // leave an unset backend empty: it is defaulted per kind
            // at create time, so a later layer overriding only `kind`
            // cannot strand the earlier kind's default backend.
            if ObjectManifest::default_backend(&o.kind).is_none() {
                return Err(anyhow!(
                    "object {:?}: unknown kind {:?} (counter | queue | stack)",
                    o.name,
                    o.kind
                ));
            }
        }
        sv.objects = objects.into_values().collect();
        Ok(())
    }

    /// Defaults, then optional file.
    pub fn load(path: Option<&Path>) -> Result<AppConfig> {
        let mut cfg = AppConfig::default();
        if let Some(p) = path {
            let doc = TomlDoc::parse_file(p).map_err(|e| anyhow!(e))?;
            cfg.apply_doc(&doc)?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_testbed() {
        let c = AppConfig::default();
        assert_eq!(c.sim.sockets, 4);
        assert_eq!(c.sim.cpus_per_socket, 44);
        assert_eq!(c.bench.aggregators, 6);
    }

    #[test]
    fn apply_doc_overrides() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse(
            r#"
            [sim]
            sockets = 2
            [sim.costs]
            cross_socket = 300
            [bench]
            grid = [1, 8, 64]
            aggregators = 4
            [service]
            addr = "0.0.0.0:9000"
            "#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.sim.sockets, 2);
        assert_eq!(c.sim.cross_socket, 300);
        assert_eq!(c.bench.grid, vec![1, 8, 64]);
        assert_eq!(c.bench.aggregators, 4);
        assert_eq!(c.service.addr, "0.0.0.0:9000");
        // untouched keys keep defaults
        assert_eq!(c.sim.cpus_per_socket, 44);
        assert_eq!(c.service.width_policy, "aimd");
        assert_eq!(c.service.max_aggregators, 12);
        assert!(!c.sim.owner_sticky);
        let doc = TomlDoc::parse("sim.costs.owner_sticky = true").unwrap();
        c.apply_doc(&doc).unwrap();
        assert!(c.sim.owner_sticky);
    }

    #[test]
    fn width_policy_keys_apply() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse(
            r#"
            [service]
            width_policy = "sqrtp"
            max_aggregators = 16
            resize_interval_ms = 100
            "#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.width_policy, "sqrtp");
        assert_eq!(c.service.max_aggregators, 16);
        assert_eq!(c.service.resize_interval_ms, 100);
    }

    #[test]
    fn objects_manifest_parses() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse(
            r#"
            [objects.orders]
            kind = "counter"
            backend = "elastic:sqrtp"
            [objects.jobs]
            kind = "queue"
            [objects.events]
            "#,
        )
        .unwrap();
        // Bare `[objects.events]` contributes no keys, so only two
        // manifests materialize.
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.objects.len(), 2);
        let jobs = c.service.objects.iter().find(|o| o.name == "jobs").unwrap();
        assert_eq!(jobs.kind, "queue");
        assert_eq!(jobs.backend, "", "unset backend stays empty until create time");
        let orders = c.service.objects.iter().find(|o| o.name == "orders").unwrap();
        assert_eq!(orders.kind, "counter");
        assert_eq!(orders.backend, "elastic:sqrtp");
        // A later layer overrides per name and merges fields.
        let doc = TomlDoc::parse("objects.orders.backend = \"elastic:aimd\"").unwrap();
        c.apply_doc(&doc).unwrap();
        let orders = c.service.objects.iter().find(|o| o.name == "orders").unwrap();
        assert_eq!(orders.kind, "counter", "kind survives the merge");
        assert_eq!(orders.backend, "elastic:aimd");
        // A layer changing only the kind must not strand the earlier
        // kind's default backend: the backend stays unset and is
        // re-defaulted for the *new* kind when the object is created.
        let doc = TomlDoc::parse("objects.jobs.kind = \"counter\"").unwrap();
        c.apply_doc(&doc).unwrap();
        let jobs = c.service.objects.iter().find(|o| o.name == "jobs").unwrap();
        assert_eq!(jobs.kind, "counter");
        assert_eq!(jobs.backend, "");
    }

    #[test]
    fn cas_policy_setting_applies_and_validates() {
        let mut c = AppConfig::default();
        assert_eq!(c.service.cas_policy, "adaptive", "adaptive pacing is the default");
        let doc = TomlDoc::parse("[service]\ncas_policy = \"exp\"").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.cas_policy, "exp");
        for ok in ["none", "const", "adaptive"] {
            let doc = TomlDoc::parse(&format!("service.cas_policy = \"{ok}\"")).unwrap();
            c.apply_doc(&doc).unwrap();
            assert_eq!(c.service.cas_policy, ok);
        }
        let doc = TomlDoc::parse("service.cas_policy = \"polite\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "unknown retry policy rejected");
    }

    #[test]
    fn shards_setting_applies_and_clamps() {
        let mut c = AppConfig::default();
        assert_eq!(c.service.shards, 1, "default is the unsharded wire protocol");
        let doc = TomlDoc::parse("[service]\nshards = 4").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.shards, 4);
        let doc = TomlDoc::parse("[service]\nshards = 0").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.shards, 1, "clamped to at least one shard");
        let doc = TomlDoc::parse("[service]\nshards = -3").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.shards, 1, "negative values clamp, not wrap");
    }

    #[test]
    fn direct_quota_manifest_field_parses() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse(
            r#"
            [objects.orders]
            kind = "counter"
            direct_quota = 2
            [objects.vip]
            kind = "counter"
            direct_quota = "1"
            "#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        let orders = c.service.objects.iter().find(|o| o.name == "orders").unwrap();
        assert_eq!(orders.direct_quota, Some(2));
        let vip = c.service.objects.iter().find(|o| o.name == "vip").unwrap();
        assert_eq!(vip.direct_quota, Some(1), "integer-valued strings accepted");
        let doc = TomlDoc::parse("[objects.orders]\ndirect_quota = \"lots\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "non-integer quota rejected");
    }

    #[test]
    fn persistence_settings_apply() {
        let mut c = AppConfig::default();
        assert_eq!(c.service.data_dir, "", "persistence is off by default");
        assert!(c.service.persist);
        assert_eq!(c.service.fsync_interval_ms, 5);
        assert_eq!(c.service.snapshot_interval_ms, 60_000);
        let doc = TomlDoc::parse(
            r#"
            [service]
            data_dir = "/var/lib/aggfunnels"
            fsync_interval_ms = 0
            snapshot_interval_ms = 30000
            persist = false
            "#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.data_dir, "/var/lib/aggfunnels");
        assert_eq!(c.service.fsync_interval_ms, 0, "0 = synchronous mode");
        assert_eq!(c.service.snapshot_interval_ms, 30_000);
        assert!(!c.service.persist, "master switch can disable data_dir");
        let doc = TomlDoc::parse("service.fsync_interval_ms = -5").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.fsync_interval_ms, 0, "negative intervals clamp");
    }

    #[test]
    fn connection_settings_apply() {
        let mut c = AppConfig::default();
        assert_eq!(c.service.io_threads, 1);
        assert_eq!(c.service.max_conns, 1024);
        assert_eq!(c.service.max_pending, 4096);
        assert!(c.service.coalesce, "coalescing defaults on");
        assert_eq!(c.service.max_ops_per_sweep, 128);
        let doc = TomlDoc::parse(
            r#"
            [service]
            io_threads = 4
            max_conns = 64
            max_pending = 256
            coalesce = false
            max_ops_per_sweep = 16
            "#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.io_threads, 4);
        assert_eq!(c.service.max_conns, 64);
        assert_eq!(c.service.max_pending, 256);
        assert!(!c.service.coalesce);
        assert_eq!(c.service.max_ops_per_sweep, 16);
        let doc = TomlDoc::parse("service.io_threads = 0").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.io_threads, 1, "clamped to at least one poll thread");
        let doc = TomlDoc::parse("service.max_ops_per_sweep = 0").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.service.max_ops_per_sweep, 1, "sweep cap clamps to at least one op");
        let doc = TomlDoc::parse("service.conn_mode = \"event\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "removed conn_mode key fails fast, not silently");
    }

    #[test]
    fn object_persist_opt_out_parses() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse(
            r#"
            [objects.scratch]
            kind = "queue"
            persist = false
            [objects.kept]
            kind = "counter"
            "#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        let scratch = c.service.objects.iter().find(|o| o.name == "scratch").unwrap();
        assert!(!scratch.persist);
        let kept = c.service.objects.iter().find(|o| o.name == "kept").unwrap();
        assert!(kept.persist, "persist defaults to true");
        let doc = TomlDoc::parse("[objects.scratch]\npersist = \"nope\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "non-boolean persist rejected");
    }

    #[test]
    fn objects_manifest_rejects_bad_entries() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse("[objects.x]\nkind = \"heap\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "unknown kind");
        let doc = TomlDoc::parse("[objects.x]\nkind = \"stack\"").unwrap();
        assert!(c.apply_doc(&doc).is_ok(), "stacks are a manifest kind now");
        let doc = TomlDoc::parse("[objects.x]\ncolour = \"red\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "unknown field");
        let doc = TomlDoc::parse("objects.x = \"flat\"").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "missing field path");
        let doc = TomlDoc::parse("[objects.x]\nkind = 3").unwrap();
        assert!(c.apply_doc(&doc).is_err(), "non-string field");
    }

    #[test]
    fn bad_grid_rejected() {
        let mut c = AppConfig::default();
        let doc = TomlDoc::parse("bench.grid = [\"x\"]").unwrap();
        assert!(c.apply_doc(&doc).is_err());
    }

    #[test]
    fn to_sim_config_roundtrip() {
        let c = AppConfig::default();
        let sc = c.sim.to_sim_config(32);
        assert_eq!(sc.threads, 32);
        assert_eq!(sc.costs.cross_socket, 200);
    }
}
