//! Client API: [`RegistryClient`] plus typed per-object handles.
//!
//! A [`RegistryClient`] is the shard-aware connection manager — it
//! performs the `shardmap` handshake, opens per-shard connections
//! lazily, and owns the control plane (`create`/`delete`/`list`/
//! `snapshot`/cluster stats). Data-plane traffic goes through typed
//! handles bound to one named object:
//!
//! ```no_run
//! use aggfunnels::service::{CreateSpec, RegistryClient};
//! # fn main() -> anyhow::Result<()> {
//! let client = RegistryClient::connect("127.0.0.1:7471")?;
//! let tickets = client.counter("tickets")?;       // typed lookup
//! let range_start = tickets.take(5)?;             // one method, not take/take_on
//! let jobs = client.create_queue("jobs", &CreateSpec::backend("lcrq+elastic"))?;
//! jobs.enqueue(7)?;
//! # Ok(()) }
//! ```
//!
//! Handles are cheap clones over the shared connection core (a
//! mutex-guarded [`ClientCore`]), so one client serves any number of
//! handles from one set of sockets. Server failures surface as
//! [`ServiceError`](super::ServiceError) values: match on the
//! machine-readable [`ErrorCode`](super::ErrorCode) (carried by the
//! wire `code` field) instead of grepping message text. Capacity
//! rejections (`ErrorCode::AtCapacity`) are retried internally within
//! a bounded policy — a rejected connection never executed anything,
//! so redialing is idempotency-safe; transport failures surface as
//! `ErrorCode::Io` and evict the cached connection without retrying,
//! because the request may already have executed server-side.
//!
//! The legacy [`TicketClient`] survives as a deprecated shim over
//! this API for one release.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::error::{service_err, ErrorCode};
use super::registry::DEFAULT_OBJECT;
use super::shard::shard_of;
use super::split_host_port;
use crate::util::json::Json;

/// Client-side retry policy for capacity rejections: a rejected
/// connection (or request) never executed anything, so redialing is
/// idempotency-safe; the bound keeps a genuinely full shard from
/// hanging the caller.
const CAPACITY_RETRIES: u32 = 40;
const CAPACITY_RETRY_DELAY: std::time::Duration = std::time::Duration::from_millis(5);

/// True when a response is a capacity rejection — keyed off the
/// machine-readable `code` first, with the structured `rejected`
/// marker and message-text fallbacks for older servers.
fn is_capacity_rejection(resp: &Json) -> bool {
    resp.get("code").and_then(Json::as_str) == Some(ErrorCode::AtCapacity.as_str())
        || resp.get("rejected").and_then(Json::as_bool) == Some(true)
        || resp
            .get("error")
            .and_then(Json::as_str)
            .is_some_and(|e| e.contains("at capacity"))
}

/// Lift a `{"ok":false,...}` reply into a typed error: the `code`
/// field picks the [`ErrorCode`] (older servers without one map to
/// `Protocol`), the message text rides along unchanged.
fn server_error(resp: &Json) -> anyhow::Error {
    let msg = resp.get("error").and_then(Json::as_str).unwrap_or("?");
    let code = resp
        .get("code")
        .and_then(Json::as_str)
        .map(ErrorCode::parse)
        .unwrap_or(ErrorCode::Protocol);
    service_err(code, msg)
}

/// One connection to one shard.
struct ClientConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ClientConn {
    fn open(addr: &str) -> Result<ClientConn> {
        let conn = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        conn.set_nodelay(true).ok();
        let writer = conn.try_clone()?;
        Ok(ClientConn { reader: BufReader::new(conn), writer })
    }

    /// Write one request and read the matching response, skipping any
    /// pushed `greeting` lines (a sharded server greets every new
    /// connection with the shard map).
    fn roundtrip_raw(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("server closed the connection"));
            }
            let resp = Json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))?;
            if resp.get("greeting").and_then(Json::as_bool) == Some(true) {
                continue;
            }
            return Ok(resp);
        }
    }
}

/// The shared connection core: the shard map plus lazily-opened
/// per-shard connections. [`RegistryClient`] and every handle hold it
/// behind one mutex — request/response on a connection is serial
/// anyway, and handles stay cheaply cloneable.
struct ClientCore {
    host: String,
    ports: Vec<u16>,
    conns: Vec<Option<ClientConn>>,
}

impl ClientCore {
    fn connect(addr: &str) -> Result<ClientCore> {
        let (host, _) = split_host_port(addr)?;
        // Bounded retry on capacity rejections, mirroring
        // `roundtrip_on`.
        let mut attempts = 0u32;
        loop {
            let mut conn = ClientConn::open(addr)?;
            let resp = conn.roundtrip_raw(&Json::obj(vec![("op", Json::str("shardmap"))]))?;
            if resp.get("ok").and_then(Json::as_bool) == Some(true)
                && resp.get("shardmap").and_then(Json::as_bool) == Some(true)
            {
                let ports: Vec<u16> = resp
                    .get("ports")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("shardmap missing ports"))?
                    .iter()
                    .filter_map(Json::as_u64)
                    .map(|p| p as u16)
                    .collect();
                if ports.is_empty() {
                    return Err(anyhow!("shardmap with no ports"));
                }
                let mut conns: Vec<Option<ClientConn>> =
                    (0..ports.len()).map(|_| None).collect();
                if ports.len() == 1 {
                    // Single shard: keep the handshake connection,
                    // it is the only one we will ever need.
                    conns[0] = Some(conn);
                } else {
                    // Sharded: drop the handshake connection instead
                    // of caching it — caching would pin resources on
                    // a shard this client's objects may never touch.
                    // Per-shard connections open lazily on first use.
                    drop(conn);
                }
                return Ok(ClientCore { host, ports, conns });
            }
            let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
            if err.contains("unknown op") {
                // A pre-shard server: one implicit shard on the
                // connected port, and the handshake error consumed
                // above keeps the line stream in sync.
                let port = conn.writer.peer_addr()?.port();
                return Ok(ClientCore { host, ports: vec![port], conns: vec![Some(conn)] });
            }
            if is_capacity_rejection(&resp) {
                attempts += 1;
                if attempts < CAPACITY_RETRIES {
                    drop(conn);
                    std::thread::sleep(CAPACITY_RETRY_DELAY);
                    continue;
                }
            }
            return Err(server_error(&resp));
        }
    }

    fn shard_for(&self, name: &str) -> usize {
        shard_of(name, self.ports.len())
    }

    fn conn_for(&mut self, shard: usize) -> Result<&mut ClientConn> {
        debug_assert!(shard < self.ports.len());
        if self.conns[shard].is_none() {
            let addr = format!("{}:{}", self.host, self.ports[shard]);
            self.conns[shard] = Some(ClientConn::open(&addr)?);
        }
        Ok(self.conns[shard].as_mut().unwrap())
    }

    fn roundtrip_on(&mut self, shard: usize, req: Json) -> Result<Json> {
        // Capacity rejections can be transient (a rejected connect
        // races slot releases), so they retry within the shared
        // bound; transport errors do NOT retry — the request may
        // already have executed server-side.
        let mut attempts = 0u32;
        loop {
            let resp = match self.conn_for(shard)?.roundtrip_raw(&req) {
                Ok(resp) => resp,
                Err(e) => {
                    // Transport failure (closed socket, bad line):
                    // evict the cached connection so the next request
                    // to this shard redials, and surface an `Io`
                    // error — distinctly typed from the server's own
                    // rejections so callers can tell a dead socket
                    // from a full shard.
                    self.conns[shard] = None;
                    return Err(service_err(ErrorCode::Io, e.to_string()));
                }
            };
            if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                if is_capacity_rejection(&resp) {
                    // The server closes after a capacity rejection;
                    // evict the dead cached connection either way.
                    self.conns[shard] = None;
                    attempts += 1;
                    if attempts < CAPACITY_RETRIES {
                        std::thread::sleep(CAPACITY_RETRY_DELAY);
                        continue;
                    }
                }
                return Err(server_error(&resp));
            }
            return Ok(resp);
        }
    }

    /// Route a named request to its owning shard.
    fn roundtrip(&mut self, name: &str, req: Json) -> Result<Json> {
        self.roundtrip_on(self.shard_for(name), req)
    }
}

/// Per-object creation options (see
/// [`RegistryClient::create_counter`] /
/// [`RegistryClient::create_queue`]).
#[derive(Clone, Debug)]
pub struct CreateSpec {
    /// Backend spec-grammar label; empty picks the kind's default.
    pub backend: String,
    /// Elastic slot capacity ceiling override.
    pub max_width: Option<u64>,
    /// §4.4 direct-thread quota (counters only).
    pub direct_quota: Option<u64>,
    /// `false` keeps the object ephemeral on a persistent server.
    pub persist: bool,
}

impl Default for CreateSpec {
    fn default() -> Self {
        CreateSpec { backend: String::new(), max_width: None, direct_quota: None, persist: true }
    }
}

impl CreateSpec {
    /// A spec with just a backend label.
    pub fn backend(backend: &str) -> Self {
        CreateSpec { backend: backend.into(), ..Self::default() }
    }

    pub fn max_width(mut self, w: u64) -> Self {
        self.max_width = Some(w);
        self
    }

    pub fn direct_quota(mut self, d: u64) -> Self {
        self.direct_quota = Some(d);
        self
    }

    /// Opt the object out of durability.
    pub fn ephemeral(mut self) -> Self {
        self.persist = false;
        self
    }
}

/// Shard-aware client for the registry service: the connection
/// manager and control plane. Data-plane traffic goes through
/// [`CounterHandle`]/[`QueueHandle`] values from
/// [`counter`](Self::counter)/[`queue`](Self::queue) (typed lookup)
/// or the `create_*` constructors.
pub struct RegistryClient {
    core: Arc<Mutex<ClientCore>>,
}

impl RegistryClient {
    /// Connect and perform the `shardmap` handshake (pre-shard
    /// servers are detected and served over the dialed port).
    pub fn connect(addr: &str) -> Result<RegistryClient> {
        Ok(RegistryClient { core: Arc::new(Mutex::new(ClientCore::connect(addr)?)) })
    }

    /// Number of shards in the connected server's map.
    pub fn shards(&self) -> usize {
        self.core.lock().unwrap().ports.len()
    }

    /// The advertised per-shard port layout.
    pub fn shard_ports(&self) -> Vec<u16> {
        self.core.lock().unwrap().ports.clone()
    }

    /// The shard index `name` routes to.
    pub fn shard_for(&self, name: &str) -> usize {
        self.core.lock().unwrap().shard_for(name)
    }

    /// Typed lookup: a handle to an existing counter. Fails with
    /// [`ErrorCode::NoSuchObject`] when absent and
    /// [`ErrorCode::WrongKind`] when `name` is a queue.
    pub fn counter(&self, name: &str) -> Result<CounterHandle> {
        self.expect_kind(name, "counter")?;
        Ok(CounterHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Typed lookup: a handle to an existing queue.
    pub fn queue(&self, name: &str) -> Result<QueueHandle> {
        self.expect_kind(name, "queue")?;
        Ok(QueueHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    fn expect_kind(&self, name: &str, want: &str) -> Result<()> {
        let stats = self.object_stats(name)?;
        let kind = stats.get("kind").and_then(Json::as_str).unwrap_or("?");
        if kind != want {
            return Err(service_err(
                ErrorCode::WrongKind,
                format!("object {name:?} is a {kind}, not a {want}"),
            ));
        }
        Ok(())
    }

    /// Create a counter and return its handle.
    pub fn create_counter(&self, name: &str, spec: &CreateSpec) -> Result<CounterHandle> {
        self.create(name, "counter", spec)?;
        Ok(CounterHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Create a queue and return its handle.
    pub fn create_queue(&self, name: &str, spec: &CreateSpec) -> Result<QueueHandle> {
        self.create(name, "queue", spec)?;
        Ok(QueueHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Untyped create (`kind`: `counter` | `queue`) — the CLI's
    /// entry point; prefer the typed constructors in code.
    pub fn create(&self, name: &str, kind: &str, spec: &CreateSpec) -> Result<()> {
        let mut pairs = vec![
            ("op", Json::str("create")),
            ("name", Json::str(name)),
            ("kind", Json::str(kind)),
        ];
        if !spec.backend.is_empty() {
            pairs.push(("backend", Json::str(spec.backend.clone())));
        }
        if let Some(w) = spec.max_width {
            pairs.push(("max_width", Json::num(w as f64)));
        }
        if let Some(d) = spec.direct_quota {
            pairs.push(("direct_quota", Json::num(d as f64)));
        }
        if !spec.persist {
            pairs.push(("persist", Json::Bool(false)));
        }
        self.core.lock().unwrap().roundtrip(name, Json::obj(pairs)).map(drop)
    }

    /// Delete a named object (any kind).
    pub fn delete(&self, name: &str) -> Result<()> {
        self.core
            .lock()
            .unwrap()
            .roundtrip(
                name,
                Json::obj(vec![("op", Json::str("delete")), ("name", Json::str(name))]),
            )
            .map(drop)
    }

    /// List registered objects across all shards, sorted by name, as
    /// `(name, kind, backend)` triples.
    pub fn list(&self) -> Result<Vec<(String, String, String)>> {
        let resp = self
            .core
            .lock()
            .unwrap()
            .roundtrip_on(0, Json::obj(vec![("op", Json::str("list"))]))?;
        let objects = resp
            .get("objects")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing objects"))?;
        objects
            .iter()
            .map(|o| {
                let field = |k: &str| {
                    o.get(k)
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("object missing {k}"))
                };
                Ok((field("name")?, field("kind")?, field("backend")?))
            })
            .collect()
    }

    /// Raw per-object stats without going through a typed handle
    /// (kind-agnostic; the CLI's `stats` path).
    pub fn object_stats(&self, name: &str) -> Result<Json> {
        self.core.lock().unwrap().roundtrip(
            name,
            Json::obj(vec![("op", Json::str("stats")), ("name", Json::str(name))]),
        )
    }

    /// The cluster aggregate (`stats` with `name = "*"`): objects,
    /// funnel batch totals and traffic merged over every shard.
    pub fn cluster_stats(&self) -> Result<Json> {
        self.core
            .lock()
            .unwrap()
            .roundtrip_on(0, Json::obj(vec![("op", Json::str("stats")), ("name", Json::str("*"))]))
    }

    /// Force a snapshot on every persistent shard. Errors when the
    /// server runs without a `data_dir`.
    pub fn snapshot(&self) -> Result<Json> {
        self.core
            .lock()
            .unwrap()
            .roundtrip_on(0, Json::obj(vec![("op", Json::str("snapshot"))]))
    }
}

/// A typed handle to one named counter. One method per operation —
/// the old `take`/`take_on` duplicate pairs collapse onto the handle,
/// whose name travels with it.
#[derive(Clone)]
pub struct CounterHandle {
    core: Arc<Mutex<ClientCore>>,
    name: String,
}

impl CounterHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Take a contiguous range of `count` values; returns its start.
    pub fn take(&self, count: u64) -> Result<u64> {
        self.take_req(count, false)
    }

    /// `take` via `Fetch&AddDirect` (§4.4), subject to the object's
    /// direct-thread quota.
    pub fn take_priority(&self, count: u64) -> Result<u64> {
        self.take_req(count, true)
    }

    fn take_req(&self, count: u64, priority: bool) -> Result<u64> {
        let mut pairs = vec![
            ("op", Json::str("take")),
            ("name", Json::str(self.name.clone())),
            ("count", Json::num(count as f64)),
        ];
        if priority {
            pairs.push(("priority", Json::Bool(true)));
        }
        let resp = self.core.lock().unwrap().roundtrip(&self.name, Json::obj(pairs))?;
        resp.get("start").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing start"))
    }

    /// Read the counter's current value.
    pub fn read(&self) -> Result<u64> {
        let resp = self.core.lock().unwrap().roundtrip(
            &self.name,
            Json::obj(vec![("op", Json::str("read")), ("name", Json::str(self.name.clone()))]),
        )?;
        resp.get("value").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing value"))
    }

    pub fn stats(&self) -> Result<Json> {
        object_stats(&self.core, &self.name)
    }

    /// Set the funnel's active width; returns the width in force.
    pub fn resize(&self, width: u64) -> Result<u64> {
        resize(&self.core, &self.name, width)
    }

    /// Swap the width policy (`fixed:<m>`, `sqrtp`, `aimd`).
    pub fn set_policy(&self, policy: &str) -> Result<String> {
        set_policy(&self.core, &self.name, policy)
    }
}

/// A typed handle to one named queue.
#[derive(Clone)]
pub struct QueueHandle {
    core: Arc<Mutex<ClientCore>>,
    name: String,
}

impl QueueHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Enqueue `item` (an integer below 2⁵³).
    pub fn enqueue(&self, item: u64) -> Result<()> {
        self.core
            .lock()
            .unwrap()
            .roundtrip(
                &self.name,
                Json::obj(vec![
                    ("op", Json::str("enqueue")),
                    ("name", Json::str(self.name.clone())),
                    ("item", Json::num(item as f64)),
                ]),
            )
            .map(drop)
    }

    /// Dequeue one item (`None` when empty).
    pub fn dequeue(&self) -> Result<Option<u64>> {
        let resp = self.core.lock().unwrap().roundtrip(
            &self.name,
            Json::obj(vec![
                ("op", Json::str("dequeue")),
                ("name", Json::str(self.name.clone())),
            ]),
        )?;
        if resp.get("empty").and_then(Json::as_bool) == Some(true) {
            return Ok(None);
        }
        resp.get("item")
            .and_then(Json::as_u64)
            .map(Some)
            .ok_or_else(|| anyhow!("missing item"))
    }

    pub fn stats(&self) -> Result<Json> {
        object_stats(&self.core, &self.name)
    }

    /// Set the funnel index's active width (elastic backends only).
    pub fn resize(&self, width: u64) -> Result<u64> {
        resize(&self.core, &self.name, width)
    }

    pub fn set_policy(&self, policy: &str) -> Result<String> {
        set_policy(&self.core, &self.name, policy)
    }
}

// The width-control and stats requests are identical for both kinds;
// shared here so the handles stay one method per wire op.
fn object_stats(core: &Arc<Mutex<ClientCore>>, name: &str) -> Result<Json> {
    core.lock().unwrap().roundtrip(
        name,
        Json::obj(vec![("op", Json::str("stats")), ("name", Json::str(name))]),
    )
}

fn resize(core: &Arc<Mutex<ClientCore>>, name: &str, width: u64) -> Result<u64> {
    let resp = core.lock().unwrap().roundtrip(
        name,
        Json::obj(vec![
            ("op", Json::str("resize")),
            ("name", Json::str(name)),
            ("width", Json::num(width as f64)),
        ]),
    )?;
    resp.get("width").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing width"))
}

fn set_policy(core: &Arc<Mutex<ClientCore>>, name: &str, policy: &str) -> Result<String> {
    let resp = core.lock().unwrap().roundtrip(
        name,
        Json::obj(vec![
            ("op", Json::str("policy")),
            ("name", Json::str(name)),
            ("policy", Json::str(policy)),
        ]),
    )?;
    resp.get("policy")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing policy"))
}

/// The pre-redesign flat client: every op as a method, `*_on`
/// duplicates included. A thin shim over [`RegistryClient`], kept for
/// one release so downstream callers can migrate at leisure.
#[deprecated(note = "use RegistryClient with CounterHandle/QueueHandle instead")]
pub struct TicketClient {
    inner: RegistryClient,
}

#[allow(deprecated)]
impl TicketClient {
    pub fn connect(addr: &str) -> Result<TicketClient> {
        Ok(TicketClient { inner: RegistryClient::connect(addr)? })
    }

    pub fn shards(&self) -> usize {
        self.inner.shards()
    }

    pub fn shard_ports(&self) -> Vec<u16> {
        self.inner.shard_ports()
    }

    pub fn shard_for(&self, name: &str) -> usize {
        self.inner.shard_for(name)
    }

    pub fn create(&mut self, name: &str, kind: &str, backend: &str) -> Result<()> {
        self.inner.create(name, kind, &CreateSpec::backend(backend))
    }

    #[allow(clippy::too_many_arguments)]
    pub fn create_with(
        &mut self,
        name: &str,
        kind: &str,
        backend: &str,
        max_width: Option<u64>,
        direct_quota: Option<u64>,
        persist: bool,
    ) -> Result<()> {
        let spec = CreateSpec {
            backend: backend.into(),
            max_width,
            direct_quota,
            persist,
        };
        self.inner.create(name, kind, &spec)
    }

    pub fn snapshot(&mut self) -> Result<Json> {
        self.inner.snapshot()
    }

    pub fn delete(&mut self, name: &str) -> Result<()> {
        self.inner.delete(name)
    }

    pub fn list(&mut self) -> Result<Vec<(String, String, String)>> {
        self.inner.list()
    }

    pub fn enqueue(&mut self, name: &str, item: u64) -> Result<()> {
        // Handles validate kind on lookup; the shim preserves the old
        // behaviour of letting the server say "wrong kind", so it
        // builds handles without the lookup roundtrip.
        QueueHandle { core: Arc::clone(&self.inner.core), name: name.into() }.enqueue(item)
    }

    pub fn dequeue(&mut self, name: &str) -> Result<Option<u64>> {
        QueueHandle { core: Arc::clone(&self.inner.core), name: name.into() }.dequeue()
    }

    pub fn take_on(&mut self, name: &str, count: u64, priority: bool) -> Result<u64> {
        let h = CounterHandle { core: Arc::clone(&self.inner.core), name: name.into() };
        if priority {
            h.take_priority(count)
        } else {
            h.take(count)
        }
    }

    pub fn take(&mut self, count: u64, priority: bool) -> Result<u64> {
        self.take_on(DEFAULT_OBJECT, count, priority)
    }

    pub fn read_on(&mut self, name: &str) -> Result<u64> {
        CounterHandle { core: Arc::clone(&self.inner.core), name: name.into() }.read()
    }

    pub fn read(&mut self) -> Result<u64> {
        self.read_on(DEFAULT_OBJECT)
    }

    pub fn stats_on(&mut self, name: &str) -> Result<Json> {
        self.inner.object_stats(name)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.stats_on(DEFAULT_OBJECT)
    }

    pub fn cluster_stats(&mut self) -> Result<Json> {
        self.inner.cluster_stats()
    }

    pub fn resize_on(&mut self, name: &str, width: u64) -> Result<u64> {
        resize(&self.inner.core, name, width)
    }

    pub fn resize(&mut self, width: u64) -> Result<u64> {
        self.resize_on(DEFAULT_OBJECT, width)
    }

    pub fn set_policy_on(&mut self, name: &str, policy: &str) -> Result<String> {
        set_policy(&self.inner.core, name, policy)
    }

    pub fn set_policy(&mut self, policy: &str) -> Result<String> {
        self.set_policy_on(DEFAULT_OBJECT, policy)
    }
}
