//! Client API: [`RegistryClient`] plus typed per-object handles.
//!
//! A [`RegistryClient`] is the shard-aware connection manager — it
//! performs the `shardmap` handshake, opens per-shard connections
//! lazily, and owns the control plane (`create`/`delete`/`list`/
//! `snapshot`/cluster stats). Data-plane traffic goes through typed
//! handles bound to one named object:
//!
//! ```no_run
//! use aggfunnels::service::{CreateSpec, RegistryClient};
//! # fn main() -> anyhow::Result<()> {
//! let client = RegistryClient::connect("127.0.0.1:7471")?;
//! let tickets = client.counter("tickets")?;       // typed lookup
//! let range_start = tickets.take(5)?;             // one method, not take/take_on
//! let jobs = client.create_queue("jobs", &CreateSpec::backend("lcrq+elastic"))?;
//! jobs.enqueue(7)?;
//! # Ok(()) }
//! ```
//!
//! Handles are cheap clones over the shared connection core (a
//! mutex-guarded [`ClientCore`]), so one client serves any number of
//! handles from one set of sockets. Server failures surface as
//! [`ServiceError`](super::ServiceError) values: match on the
//! machine-readable [`ErrorCode`](super::ErrorCode) (carried by the
//! wire `code` field) instead of grepping message text. Capacity
//! rejections (`ErrorCode::AtCapacity`) are retried internally within
//! a bounded policy — a rejected connection never executed anything,
//! so redialing is idempotency-safe; transport failures surface as
//! `ErrorCode::Io` and evict the cached connection without retrying,
//! because the request may already have executed server-side.
//!
//! [`RegistryClient::connect_binary`] negotiates the length-prefixed
//! binary framing on every connection it opens; the handle APIs are
//! identical in either mode, and [`RegistryClient::call_many`]
//! pipelines a slice of [`BinRequest`] values — all requests written
//! before any response is read — over whichever wire the client
//! speaks (binary frames, or the JSON line grammar as fallback).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::error::{code_of, service_err, ErrorCode};
use super::frame::{self, BinRequest, BinResponse, Item};
use super::shard::shard_of;
use super::split_host_port;
use crate::util::json::Json;

/// Client-side retry policy for capacity rejections: a rejected
/// connection (or request) never executed anything, so redialing is
/// idempotency-safe; the bound keeps a genuinely full shard from
/// hanging the caller.
const CAPACITY_RETRIES: u32 = 40;
const CAPACITY_RETRY_DELAY: std::time::Duration = std::time::Duration::from_millis(5);

/// True when a response is a capacity rejection — keyed off the
/// machine-readable `code` first, with the structured `rejected`
/// marker and message-text fallbacks for older servers.
fn is_capacity_rejection(resp: &Json) -> bool {
    resp.get("code").and_then(Json::as_str) == Some(ErrorCode::AtCapacity.as_str())
        || resp.get("rejected").and_then(Json::as_bool) == Some(true)
        || resp
            .get("error")
            .and_then(Json::as_str)
            .is_some_and(|e| e.contains("at capacity"))
}

/// Lift a `{"ok":false,...}` reply into a typed error: the `code`
/// field picks the [`ErrorCode`] (older servers without one map to
/// `Protocol`), the message text rides along unchanged.
fn server_error(resp: &Json) -> anyhow::Error {
    let msg = resp.get("error").and_then(Json::as_str).unwrap_or("?");
    let code = resp
        .get("code")
        .and_then(Json::as_str)
        .map(ErrorCode::parse)
        .unwrap_or(ErrorCode::Protocol);
    service_err(code, msg)
}

/// One connection to one shard, speaking either the JSON line
/// grammar or (after negotiation at open) the binary framing.
struct ClientConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// True once the binary hello handshake has completed.
    binary: bool,
    /// Undecoded bytes ahead of the next binary frame boundary.
    inbuf: Vec<u8>,
}

impl ClientConn {
    fn open(addr: &str, binary: bool) -> Result<ClientConn> {
        let conn = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        conn.set_nodelay(true).ok();
        let writer = conn.try_clone()?;
        let mut conn =
            ClientConn { reader: BufReader::new(conn), writer, binary, inbuf: Vec::new() };
        if binary {
            conn.negotiate_binary()?;
        }
        Ok(conn)
    }

    /// Send the magic preamble and consume the server's hello frame.
    /// A sharded server pushes its JSON greeting line (and a full
    /// server its rejection line) *before* negotiation resolves, so a
    /// leading `{` byte is read as a pushed line — never the hello,
    /// whose first byte is its frame's length prefix (well under
    /// `{` = 0x7B).
    fn negotiate_binary(&mut self) -> Result<()> {
        self.writer.write_all(&frame::WIRE_MAGIC)?;
        loop {
            let buf = self.reader.fill_buf()?;
            if buf.is_empty() {
                return Err(anyhow!("server closed the connection during negotiation"));
            }
            if buf[0] != b'{' {
                break;
            }
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            let doc = Json::parse(&line).map_err(|e| anyhow!("bad negotiation line: {e}"))?;
            if doc.get("greeting").and_then(Json::as_bool) == Some(true) {
                continue;
            }
            // A pre-negotiation rejection (connection slots full):
            // typed so the caller's bounded capacity retry applies.
            return Err(server_error(&doc));
        }
        match self.read_response()? {
            BinResponse::Json(doc)
                if Json::parse(&doc)
                    .ok()
                    .and_then(|j| j.get("binary").and_then(Json::as_bool))
                    == Some(true) =>
            {
                Ok(())
            }
            other => Err(anyhow!("unexpected hello {other:?} from binary negotiation")),
        }
    }

    /// Read one complete binary frame payload, buffering through the
    /// same incremental [`frame::decode_wire_frame`] the server uses.
    fn read_frame(&mut self) -> Result<Vec<u8>> {
        loop {
            match frame::decode_wire_frame(&self.inbuf) {
                frame::WireDecode::Frame { payload, consumed } => {
                    self.inbuf.drain(..consumed);
                    return Ok(payload);
                }
                frame::WireDecode::Partial => {
                    let chunk = self.reader.fill_buf()?;
                    if chunk.is_empty() {
                        return Err(anyhow!("server closed the connection"));
                    }
                    let n = chunk.len();
                    self.inbuf.extend_from_slice(chunk);
                    self.reader.consume(n);
                }
                frame::WireDecode::Bad(msg) => return Err(anyhow!("bad frame: {msg}")),
            }
        }
    }

    /// Read and decode one binary response frame.
    fn read_response(&mut self) -> Result<BinResponse> {
        let payload = self.read_frame()?;
        frame::decode_response(&payload).map_err(|e| anyhow!("bad response frame: {e}"))
    }

    /// Read one JSON response line, skipping pushed `greeting` lines
    /// (a sharded server greets every new connection with the shard
    /// map).
    fn read_json_line(&mut self) -> Result<Json> {
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("server closed the connection"));
            }
            let resp = Json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))?;
            if resp.get("greeting").and_then(Json::as_bool) == Some(true) {
                continue;
            }
            return Ok(resp);
        }
    }

    /// Write one JSON request and read the matching response. On a
    /// binary connection the document travels wrapped in a JSON frame
    /// and typed error frames fold back into the `{"ok":false,...}`
    /// shape, so callers never see the difference.
    fn roundtrip_raw(&mut self, req: &Json) -> Result<Json> {
        if self.binary {
            let mut framed = Vec::new();
            encode_framed(&BinRequest::Json(req.to_string()), &mut framed);
            self.writer.write_all(&framed)?;
            return match self.read_response()? {
                BinResponse::Json(doc) => {
                    Json::parse(&doc).map_err(|e| anyhow!("bad response: {e}"))
                }
                BinResponse::Err { code, msg } => Ok(Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(msg)),
                    ("code", Json::str(code.as_str())),
                ])),
                other => Err(anyhow!("unexpected response {other:?} to a json frame")),
            };
        }
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.read_json_line()
    }

    /// Pipeline a batch: write every request back-to-back, then read
    /// the responses in order. One syscall's worth of writes and no
    /// per-request round-trip wait — this is the client half of the
    /// batching story, feeding the server enough concurrent ops to
    /// fill funnel batches.
    fn pipeline(&mut self, reqs: &[&BinRequest]) -> Result<Vec<BinResponse>> {
        if self.binary {
            let mut framed = Vec::new();
            for req in reqs {
                encode_framed(req, &mut framed);
            }
            self.writer.write_all(&framed)?;
            return reqs.iter().map(|_| self.read_response()).collect();
        }
        let mut lines = String::new();
        for req in reqs {
            lines.push_str(&req_to_line(req));
            lines.push('\n');
        }
        self.writer.write_all(lines.as_bytes())?;
        reqs.iter().map(|req| Ok(json_to_resp(req, &self.read_json_line()?))).collect()
    }
}

/// Serialize one request as a checksummed wire frame.
fn encode_framed(req: &BinRequest, out: &mut Vec<u8>) {
    let mut payload = Vec::new();
    frame::encode_request(req, &mut payload);
    frame::encode_frame(&payload, out);
}

/// The JSON line grammar spelling of a binary request — the fallback
/// wire for [`RegistryClient::call_many`] on a non-binary client.
fn req_to_line(req: &BinRequest) -> String {
    match req {
        BinRequest::Json(doc) => doc.clone(),
        BinRequest::Take { name, count, priority } => {
            let mut pairs = vec![
                ("op", Json::str("take")),
                ("name", Json::str(name.clone())),
                ("count", Json::num(*count as f64)),
            ];
            if *priority {
                pairs.push(("priority", Json::Bool(true)));
            }
            Json::obj(pairs).to_string()
        }
        BinRequest::Read { name } => {
            Json::obj(vec![("op", Json::str("read")), ("name", Json::str(name.clone()))])
                .to_string()
        }
        BinRequest::Enqueue { name, items } => Json::obj(vec![
            ("op", Json::str("enqueue")),
            ("name", Json::str(name.clone())),
            ("items", Json::arr(items.iter().map(Item::to_json))),
        ])
        .to_string(),
        BinRequest::Dequeue { name, count } => Json::obj(vec![
            ("op", Json::str("dequeue")),
            ("name", Json::str(name.clone())),
            ("count", Json::num(*count as f64)),
        ])
        .to_string(),
        BinRequest::Push { name, items } => Json::obj(vec![
            ("op", Json::str("push")),
            ("name", Json::str(name.clone())),
            ("items", Json::arr(items.iter().map(Item::to_json))),
        ])
        .to_string(),
        BinRequest::Pop { name, count } => Json::obj(vec![
            ("op", Json::str("pop")),
            ("name", Json::str(name.clone())),
            ("count", Json::num(*count as f64)),
        ])
        .to_string(),
    }
}

/// Fold a JSON line reply back into the typed response the matching
/// request would have produced on the binary wire.
fn json_to_resp(req: &BinRequest, resp: &Json) -> BinResponse {
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        let err = server_error(resp);
        return BinResponse::Err { code: code_of(&err), msg: err.to_string() };
    }
    let missing = |field: &str| BinResponse::Err {
        code: ErrorCode::Protocol,
        msg: format!("response missing {field}"),
    };
    match req {
        BinRequest::Json(_) => BinResponse::Json(resp.to_string()),
        BinRequest::Take { .. } => match resp.get("start").and_then(Json::as_u64) {
            Some(start) => BinResponse::Start(start),
            None => missing("start"),
        },
        BinRequest::Read { .. } => match resp.get("value").and_then(Json::as_u64) {
            Some(value) => BinResponse::Value(value),
            None => missing("value"),
        },
        BinRequest::Enqueue { .. } => match resp.get("count").and_then(Json::as_u64) {
            Some(count) => BinResponse::Enqueued(count as u32),
            None => missing("count"),
        },
        BinRequest::Dequeue { .. } | BinRequest::Pop { .. } => {
            match resp.get("items").and_then(Json::as_arr) {
                Some(arr) => {
                    let items: Option<Vec<Item>> = arr.iter().map(Item::from_json).collect();
                    match (items, req) {
                        (Some(items), BinRequest::Pop { .. }) => BinResponse::Popped(items),
                        (Some(items), _) => BinResponse::Items(items),
                        (None, _) => missing("parseable items"),
                    }
                }
                None => missing("items"),
            }
        }
        BinRequest::Push { .. } => match resp.get("count").and_then(Json::as_u64) {
            Some(count) => BinResponse::Pushed(count as u32),
            None => missing("count"),
        },
    }
}

/// The shared connection core: the shard map plus lazily-opened
/// per-shard connections. [`RegistryClient`] and every handle hold it
/// behind one mutex — request/response on a connection is serial
/// anyway, and handles stay cheaply cloneable.
struct ClientCore {
    host: String,
    ports: Vec<u16>,
    conns: Vec<Option<ClientConn>>,
    /// Negotiate binary framing on every connection this core opens.
    binary: bool,
}

impl ClientCore {
    fn connect(addr: &str, binary: bool) -> Result<ClientCore> {
        let (host, _) = split_host_port(addr)?;
        // Bounded retry on capacity rejections, mirroring
        // `roundtrip_on`.
        let mut attempts = 0u32;
        loop {
            let mut conn = match ClientConn::open(addr, binary) {
                Ok(conn) => conn,
                Err(e) => {
                    // Pre-negotiation rejections surface as open
                    // errors on a binary client; retry those within
                    // the same bound.
                    if code_of(&e) == ErrorCode::AtCapacity {
                        attempts += 1;
                        if attempts < CAPACITY_RETRIES {
                            std::thread::sleep(CAPACITY_RETRY_DELAY);
                            continue;
                        }
                    }
                    return Err(e);
                }
            };
            let resp = conn.roundtrip_raw(&Json::obj(vec![("op", Json::str("shardmap"))]))?;
            if resp.get("ok").and_then(Json::as_bool) == Some(true)
                && resp.get("shardmap").and_then(Json::as_bool) == Some(true)
            {
                let ports: Vec<u16> = resp
                    .get("ports")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("shardmap missing ports"))?
                    .iter()
                    .filter_map(Json::as_u64)
                    .map(|p| p as u16)
                    .collect();
                if ports.is_empty() {
                    return Err(anyhow!("shardmap with no ports"));
                }
                let mut conns: Vec<Option<ClientConn>> =
                    (0..ports.len()).map(|_| None).collect();
                if ports.len() == 1 {
                    // Single shard: keep the handshake connection,
                    // it is the only one we will ever need.
                    conns[0] = Some(conn);
                } else {
                    // Sharded: drop the handshake connection instead
                    // of caching it — caching would pin resources on
                    // a shard this client's objects may never touch.
                    // Per-shard connections open lazily on first use.
                    drop(conn);
                }
                return Ok(ClientCore { host, ports, conns, binary });
            }
            let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
            if err.contains("unknown op") {
                // A pre-shard server: one implicit shard on the
                // connected port, and the handshake error consumed
                // above keeps the line stream in sync.
                let port = conn.writer.peer_addr()?.port();
                return Ok(ClientCore {
                    host,
                    ports: vec![port],
                    conns: vec![Some(conn)],
                    binary,
                });
            }
            if is_capacity_rejection(&resp) {
                attempts += 1;
                if attempts < CAPACITY_RETRIES {
                    drop(conn);
                    std::thread::sleep(CAPACITY_RETRY_DELAY);
                    continue;
                }
            }
            return Err(server_error(&resp));
        }
    }

    fn shard_for(&self, name: &str) -> usize {
        shard_of(name, self.ports.len())
    }

    fn conn_for(&mut self, shard: usize) -> Result<&mut ClientConn> {
        debug_assert!(shard < self.ports.len());
        if self.conns[shard].is_none() {
            let addr = format!("{}:{}", self.host, self.ports[shard]);
            self.conns[shard] = Some(ClientConn::open(&addr, self.binary)?);
        }
        Ok(self.conns[shard].as_mut().unwrap())
    }

    fn roundtrip_on(&mut self, shard: usize, req: Json) -> Result<Json> {
        // Capacity rejections can be transient (a rejected connect
        // races slot releases), so they retry within the shared
        // bound; transport errors do NOT retry — the request may
        // already have executed server-side.
        let mut attempts = 0u32;
        loop {
            let conn = match self.conn_for(shard) {
                Ok(conn) => conn,
                Err(e) => {
                    if code_of(&e) == ErrorCode::AtCapacity {
                        attempts += 1;
                        if attempts < CAPACITY_RETRIES {
                            std::thread::sleep(CAPACITY_RETRY_DELAY);
                            continue;
                        }
                    }
                    return Err(e);
                }
            };
            let resp = match conn.roundtrip_raw(&req) {
                Ok(resp) => resp,
                Err(e) => {
                    // Transport failure (closed socket, bad line):
                    // evict the cached connection so the next request
                    // to this shard redials, and surface an `Io`
                    // error — distinctly typed from the server's own
                    // rejections so callers can tell a dead socket
                    // from a full shard.
                    self.conns[shard] = None;
                    return Err(service_err(ErrorCode::Io, e.to_string()));
                }
            };
            if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                if is_capacity_rejection(&resp) {
                    // The server closes after a capacity rejection;
                    // evict the dead cached connection either way.
                    self.conns[shard] = None;
                    attempts += 1;
                    if attempts < CAPACITY_RETRIES {
                        std::thread::sleep(CAPACITY_RETRY_DELAY);
                        continue;
                    }
                }
                return Err(server_error(&resp));
            }
            return Ok(resp);
        }
    }

    /// Route a named request to its owning shard.
    fn roundtrip(&mut self, name: &str, req: Json) -> Result<Json> {
        self.roundtrip_on(self.shard_for(name), req)
    }

    /// Pipeline a batch of requests on one shard's connection.
    /// Per-request failures come back as [`BinResponse::Err`] values;
    /// the `Result` layer is reserved for transport death (which
    /// evicts the connection, same policy as `roundtrip_on`).
    fn pipeline_on(&mut self, shard: usize, reqs: &[&BinRequest]) -> Result<Vec<BinResponse>> {
        let mut attempts = 0u32;
        loop {
            let conn = match self.conn_for(shard) {
                Ok(conn) => conn,
                Err(e) => {
                    if code_of(&e) == ErrorCode::AtCapacity {
                        attempts += 1;
                        if attempts < CAPACITY_RETRIES {
                            std::thread::sleep(CAPACITY_RETRY_DELAY);
                            continue;
                        }
                    }
                    return Err(e);
                }
            };
            return match conn.pipeline(reqs) {
                Ok(resps) => Ok(resps),
                Err(e) => {
                    self.conns[shard] = None;
                    Err(service_err(ErrorCode::Io, e.to_string()))
                }
            };
        }
    }

    /// One data-plane request through the pipeline path, with the
    /// same bounded capacity retry as `roundtrip_on` (the server
    /// closes after a capacity rejection, so the connection is
    /// evicted before redialing).
    fn call(&mut self, name: &str, req: BinRequest) -> Result<BinResponse> {
        let shard = self.shard_for(name);
        let mut attempts = 0u32;
        loop {
            let resp = self
                .pipeline_on(shard, &[&req])?
                .pop()
                .expect("pipeline returns one response per request");
            match resp {
                BinResponse::Err { code: ErrorCode::AtCapacity, msg } => {
                    self.conns[shard] = None;
                    attempts += 1;
                    if attempts < CAPACITY_RETRIES {
                        std::thread::sleep(CAPACITY_RETRY_DELAY);
                        continue;
                    }
                    return Err(service_err(ErrorCode::AtCapacity, msg));
                }
                BinResponse::Err { code, msg } => return Err(service_err(code, msg)),
                other => return Ok(other),
            }
        }
    }
}

/// Per-object creation options (see
/// [`RegistryClient::create_counter`] /
/// [`RegistryClient::create_queue`]).
#[derive(Clone, Debug)]
pub struct CreateSpec {
    /// Backend spec-grammar label; empty picks the kind's default.
    pub backend: String,
    /// Elastic slot capacity ceiling override.
    pub max_width: Option<u64>,
    /// §4.4 direct-thread quota (counters only).
    pub direct_quota: Option<u64>,
    /// `false` keeps the object ephemeral on a persistent server.
    pub persist: bool,
}

impl Default for CreateSpec {
    fn default() -> Self {
        CreateSpec { backend: String::new(), max_width: None, direct_quota: None, persist: true }
    }
}

impl CreateSpec {
    /// A spec with just a backend label.
    pub fn backend(backend: &str) -> Self {
        CreateSpec { backend: backend.into(), ..Self::default() }
    }

    pub fn max_width(mut self, w: u64) -> Self {
        self.max_width = Some(w);
        self
    }

    pub fn direct_quota(mut self, d: u64) -> Self {
        self.direct_quota = Some(d);
        self
    }

    /// Opt the object out of durability.
    pub fn ephemeral(mut self) -> Self {
        self.persist = false;
        self
    }
}

/// Shard-aware client for the registry service: the connection
/// manager and control plane. Data-plane traffic goes through
/// [`CounterHandle`]/[`QueueHandle`]/[`StackHandle`] values from
/// [`counter`](Self::counter)/[`queue`](Self::queue)/
/// [`stack`](Self::stack) (typed lookup) or the `create_*`
/// constructors.
pub struct RegistryClient {
    core: Arc<Mutex<ClientCore>>,
}

impl RegistryClient {
    /// Connect and perform the `shardmap` handshake (pre-shard
    /// servers are detected and served over the dialed port). Every
    /// connection speaks the JSON line grammar.
    pub fn connect(addr: &str) -> Result<RegistryClient> {
        Ok(RegistryClient { core: Arc::new(Mutex::new(ClientCore::connect(addr, false)?)) })
    }

    /// Connect with binary framing negotiated on every connection
    /// this client opens. The API is identical to a JSON client;
    /// data-plane ops travel as typed frames and control-plane JSON
    /// documents ride inside `OP_JSON` frames.
    pub fn connect_binary(addr: &str) -> Result<RegistryClient> {
        Ok(RegistryClient { core: Arc::new(Mutex::new(ClientCore::connect(addr, true)?)) })
    }

    /// Whether this client negotiated binary framing at connect time.
    pub fn is_binary(&self) -> bool {
        self.core.lock().unwrap().binary
    }

    /// Pipeline a batch of requests: group by owning shard, write
    /// every request before reading any response, and return the
    /// responses in request order. Per-request failures come back as
    /// [`BinResponse::Err`] values so one bad op does not discard its
    /// batchmates' results; `Err` at the `Result` layer means the
    /// transport died. Wrapped [`BinRequest::Json`] documents route
    /// to shard 0 (the control-plane convention).
    pub fn call_many(&self, reqs: &[BinRequest]) -> Result<Vec<BinResponse>> {
        let mut core = self.core.lock().unwrap();
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); core.ports.len()];
        for (i, req) in reqs.iter().enumerate() {
            let shard = req.name().map_or(0, |name| core.shard_for(name));
            by_shard[shard].push(i);
        }
        let mut out: Vec<Option<BinResponse>> = reqs.iter().map(|_| None).collect();
        for (shard, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let batch: Vec<&BinRequest> = idxs.iter().map(|&i| &reqs[i]).collect();
            let resps = core.pipeline_on(shard, &batch)?;
            for (&i, resp) in idxs.iter().zip(resps) {
                out[i] = Some(resp);
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every request answered")).collect())
    }

    /// Number of shards in the connected server's map.
    pub fn shards(&self) -> usize {
        self.core.lock().unwrap().ports.len()
    }

    /// The advertised per-shard port layout.
    pub fn shard_ports(&self) -> Vec<u16> {
        self.core.lock().unwrap().ports.clone()
    }

    /// The shard index `name` routes to.
    pub fn shard_for(&self, name: &str) -> usize {
        self.core.lock().unwrap().shard_for(name)
    }

    /// Typed lookup: a handle to an existing counter. Fails with
    /// [`ErrorCode::NoSuchObject`] when absent and
    /// [`ErrorCode::WrongKind`] when `name` is a queue.
    pub fn counter(&self, name: &str) -> Result<CounterHandle> {
        self.expect_kind(name, "counter")?;
        Ok(CounterHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Typed lookup: a handle to an existing queue.
    pub fn queue(&self, name: &str) -> Result<QueueHandle> {
        self.expect_kind(name, "queue")?;
        Ok(QueueHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Typed lookup: a handle to an existing stack.
    pub fn stack(&self, name: &str) -> Result<StackHandle> {
        self.expect_kind(name, "stack")?;
        Ok(StackHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    fn expect_kind(&self, name: &str, want: &str) -> Result<()> {
        let stats = self.object_stats(name)?;
        let kind = stats.get("kind").and_then(Json::as_str).unwrap_or("?");
        if kind != want {
            return Err(service_err(
                ErrorCode::WrongKind,
                format!("object {name:?} is a {kind}, not a {want}"),
            ));
        }
        Ok(())
    }

    /// Create a counter and return its handle.
    pub fn create_counter(&self, name: &str, spec: &CreateSpec) -> Result<CounterHandle> {
        self.create(name, "counter", spec)?;
        Ok(CounterHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Create a queue and return its handle.
    pub fn create_queue(&self, name: &str, spec: &CreateSpec) -> Result<QueueHandle> {
        self.create(name, "queue", spec)?;
        Ok(QueueHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Create a stack and return its handle.
    pub fn create_stack(&self, name: &str, spec: &CreateSpec) -> Result<StackHandle> {
        self.create(name, "stack", spec)?;
        Ok(StackHandle { core: Arc::clone(&self.core), name: name.to_string() })
    }

    /// Untyped create (`kind`: `counter` | `queue` | `stack`) — the
    /// CLI's entry point; prefer the typed constructors in code.
    pub fn create(&self, name: &str, kind: &str, spec: &CreateSpec) -> Result<()> {
        let mut pairs = vec![
            ("op", Json::str("create")),
            ("name", Json::str(name)),
            ("kind", Json::str(kind)),
        ];
        if !spec.backend.is_empty() {
            pairs.push(("backend", Json::str(spec.backend.clone())));
        }
        if let Some(w) = spec.max_width {
            pairs.push(("max_width", Json::num(w as f64)));
        }
        if let Some(d) = spec.direct_quota {
            pairs.push(("direct_quota", Json::num(d as f64)));
        }
        if !spec.persist {
            pairs.push(("persist", Json::Bool(false)));
        }
        self.core.lock().unwrap().roundtrip(name, Json::obj(pairs)).map(drop)
    }

    /// Delete a named object (any kind).
    pub fn delete(&self, name: &str) -> Result<()> {
        self.core
            .lock()
            .unwrap()
            .roundtrip(
                name,
                Json::obj(vec![("op", Json::str("delete")), ("name", Json::str(name))]),
            )
            .map(drop)
    }

    /// List registered objects across all shards, sorted by name, as
    /// `(name, kind, backend)` triples.
    pub fn list(&self) -> Result<Vec<(String, String, String)>> {
        let resp = self
            .core
            .lock()
            .unwrap()
            .roundtrip_on(0, Json::obj(vec![("op", Json::str("list"))]))?;
        let objects = resp
            .get("objects")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing objects"))?;
        objects
            .iter()
            .map(|o| {
                let field = |k: &str| {
                    o.get(k)
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("object missing {k}"))
                };
                Ok((field("name")?, field("kind")?, field("backend")?))
            })
            .collect()
    }

    /// Raw per-object stats without going through a typed handle
    /// (kind-agnostic; the CLI's `stats` path).
    pub fn object_stats(&self, name: &str) -> Result<Json> {
        self.core.lock().unwrap().roundtrip(
            name,
            Json::obj(vec![("op", Json::str("stats")), ("name", Json::str(name))]),
        )
    }

    /// The cluster aggregate (`stats` with `name = "*"`): objects,
    /// funnel batch totals and traffic merged over every shard.
    pub fn cluster_stats(&self) -> Result<Json> {
        self.core
            .lock()
            .unwrap()
            .roundtrip_on(0, Json::obj(vec![("op", Json::str("stats")), ("name", Json::str("*"))]))
    }

    /// Force a snapshot on every persistent shard. Errors when the
    /// server runs without a `data_dir`.
    pub fn snapshot(&self) -> Result<Json> {
        self.core
            .lock()
            .unwrap()
            .roundtrip_on(0, Json::obj(vec![("op", Json::str("snapshot"))]))
    }
}

/// A typed handle to one named counter. One method per operation —
/// the old `take`/`take_on` duplicate pairs collapse onto the handle,
/// whose name travels with it.
#[derive(Clone)]
pub struct CounterHandle {
    core: Arc<Mutex<ClientCore>>,
    name: String,
}

impl CounterHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Take a contiguous range of `count` values; returns its start.
    pub fn take(&self, count: u64) -> Result<u64> {
        self.take_req(count, false)
    }

    /// `take` via `Fetch&AddDirect` (§4.4), subject to the object's
    /// direct-thread quota.
    pub fn take_priority(&self, count: u64) -> Result<u64> {
        self.take_req(count, true)
    }

    fn take_req(&self, count: u64, priority: bool) -> Result<u64> {
        let req = BinRequest::Take { name: self.name.clone(), count, priority };
        match self.core.lock().unwrap().call(&self.name, req)? {
            BinResponse::Start(start) => Ok(start),
            other => Err(anyhow!("unexpected take response {other:?}")),
        }
    }

    /// Take several ranges in one pipelined batch: one wire write,
    /// responses read in order, each entry the start of its range.
    /// The batch lands on the server close enough together to share
    /// funnel batches instead of paying a round-trip per range.
    pub fn take_batch(&self, counts: &[u64]) -> Result<Vec<u64>> {
        let reqs: Vec<BinRequest> = counts
            .iter()
            .map(|&count| BinRequest::Take { name: self.name.clone(), count, priority: false })
            .collect();
        let refs: Vec<&BinRequest> = reqs.iter().collect();
        let mut core = self.core.lock().unwrap();
        let shard = core.shard_for(&self.name);
        core.pipeline_on(shard, &refs)?
            .into_iter()
            .map(|resp| match resp {
                BinResponse::Start(start) => Ok(start),
                BinResponse::Err { code, msg } => Err(service_err(code, msg)),
                other => Err(anyhow!("unexpected take response {other:?}")),
            })
            .collect()
    }

    /// Read the counter's current value.
    pub fn read(&self) -> Result<u64> {
        let req = BinRequest::Read { name: self.name.clone() };
        match self.core.lock().unwrap().call(&self.name, req)? {
            BinResponse::Value(value) => Ok(value),
            other => Err(anyhow!("unexpected read response {other:?}")),
        }
    }

    pub fn stats(&self) -> Result<Json> {
        object_stats(&self.core, &self.name)
    }

    /// Set the funnel's active width; returns the width in force.
    pub fn resize(&self, width: u64) -> Result<u64> {
        resize(&self.core, &self.name, width)
    }

    /// Swap the width policy (`fixed:<m>`, `sqrtp`, `aimd`).
    pub fn set_policy(&self, policy: &str) -> Result<String> {
        set_policy(&self.core, &self.name, policy)
    }
}

/// A typed handle to one named queue.
#[derive(Clone)]
pub struct QueueHandle {
    core: Arc<Mutex<ClientCore>>,
    name: String,
}

impl QueueHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Enqueue `item` (an integer below 2⁵³).
    pub fn enqueue(&self, item: u64) -> Result<()> {
        self.enqueue_batch(vec![Item::Int(item)]).map(drop)
    }

    /// Enqueue a byte-string payload (at most
    /// [`frame::MAX_ITEM_BYTES`] bytes).
    pub fn enqueue_bytes(&self, data: &[u8]) -> Result<()> {
        self.enqueue_batch(vec![Item::Bytes(data.to_vec())]).map(drop)
    }

    /// Enqueue a batch of items as one wire frame, mapped onto funnel
    /// batches server-side. Returns the number enqueued (always the
    /// full batch on success — enqueue is all-or-error per item, and
    /// the server stops at the first failure).
    pub fn enqueue_batch(&self, items: Vec<Item>) -> Result<u32> {
        let req = BinRequest::Enqueue { name: self.name.clone(), items };
        match self.core.lock().unwrap().call(&self.name, req)? {
            BinResponse::Enqueued(count) => Ok(count),
            other => Err(anyhow!("unexpected enqueue response {other:?}")),
        }
    }

    /// Dequeue one integer item (`None` when empty). Fails with a
    /// typed `Protocol` error when the head of the queue is a
    /// byte-string payload — use [`dequeue_item`](Self::dequeue_item)
    /// for mixed-type queues. The item IS consumed in that case.
    pub fn dequeue(&self) -> Result<Option<u64>> {
        match self.dequeue_item()? {
            None => Ok(None),
            Some(Item::Int(v)) => Ok(Some(v)),
            Some(Item::Bytes(_)) => Err(service_err(
                ErrorCode::Protocol,
                "dequeued a byte-string item; use dequeue_item for byte payloads",
            )),
        }
    }

    /// Dequeue one item of either type (`None` when empty).
    pub fn dequeue_item(&self) -> Result<Option<Item>> {
        Ok(self.dequeue_batch(1)?.into_iter().next())
    }

    /// Dequeue up to `count` items in one wire frame. Returns fewer
    /// (possibly zero) when the queue drains first.
    pub fn dequeue_batch(&self, count: u32) -> Result<Vec<Item>> {
        let req = BinRequest::Dequeue { name: self.name.clone(), count };
        match self.core.lock().unwrap().call(&self.name, req)? {
            BinResponse::Items(items) => Ok(items),
            other => Err(anyhow!("unexpected dequeue response {other:?}")),
        }
    }

    pub fn stats(&self) -> Result<Json> {
        object_stats(&self.core, &self.name)
    }

    /// Set the funnel index's active width (elastic backends only).
    pub fn resize(&self, width: u64) -> Result<u64> {
        resize(&self.core, &self.name, width)
    }

    pub fn set_policy(&self, policy: &str) -> Result<String> {
        set_policy(&self.core, &self.name, policy)
    }
}

/// A typed handle to one named stack (LIFO; elimination-backed
/// backends server-side).
#[derive(Clone)]
pub struct StackHandle {
    core: Arc<Mutex<ClientCore>>,
    name: String,
}

impl StackHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Push `item` (an integer below 2⁵³).
    pub fn push(&self, item: u64) -> Result<()> {
        self.push_batch(vec![Item::Int(item)]).map(drop)
    }

    /// Push a byte-string payload (at most
    /// [`frame::MAX_ITEM_BYTES`] bytes).
    pub fn push_bytes(&self, data: &[u8]) -> Result<()> {
        self.push_batch(vec![Item::Bytes(data.to_vec())]).map(drop)
    }

    /// Push a batch of items as one wire frame, applied in order —
    /// the last item of the batch ends up on top. Returns the number
    /// pushed (always the full batch on success; the server stops at
    /// the first failure).
    pub fn push_batch(&self, items: Vec<Item>) -> Result<u32> {
        let req = BinRequest::Push { name: self.name.clone(), items };
        match self.core.lock().unwrap().call(&self.name, req)? {
            BinResponse::Pushed(count) => Ok(count),
            other => Err(anyhow!("unexpected push response {other:?}")),
        }
    }

    /// Pop one integer item (`None` when empty). Fails with a typed
    /// `Protocol` error when the top of the stack is a byte-string
    /// payload — use [`pop_item`](Self::pop_item) for mixed-type
    /// stacks. The item IS consumed in that case.
    pub fn pop(&self) -> Result<Option<u64>> {
        match self.pop_item()? {
            None => Ok(None),
            Some(Item::Int(v)) => Ok(Some(v)),
            Some(Item::Bytes(_)) => Err(service_err(
                ErrorCode::Protocol,
                "popped a byte-string item; use pop_item for byte payloads",
            )),
        }
    }

    /// Pop one item of either type (`None` when empty).
    pub fn pop_item(&self) -> Result<Option<Item>> {
        Ok(self.pop_batch(1)?.into_iter().next())
    }

    /// Pop up to `count` items in one wire frame, top first. Returns
    /// fewer (possibly zero) when the stack drains first.
    pub fn pop_batch(&self, count: u32) -> Result<Vec<Item>> {
        let req = BinRequest::Pop { name: self.name.clone(), count };
        match self.core.lock().unwrap().call(&self.name, req)? {
            BinResponse::Popped(items) => Ok(items),
            other => Err(anyhow!("unexpected pop response {other:?}")),
        }
    }

    pub fn stats(&self) -> Result<Json> {
        object_stats(&self.core, &self.name)
    }

    /// Set the elimination layer's active width (elastic backends
    /// only).
    pub fn resize(&self, width: u64) -> Result<u64> {
        resize(&self.core, &self.name, width)
    }

    pub fn set_policy(&self, policy: &str) -> Result<String> {
        set_policy(&self.core, &self.name, policy)
    }
}

// The width-control and stats requests are identical across kinds;
// shared here so the handles stay one method per wire op.
fn object_stats(core: &Arc<Mutex<ClientCore>>, name: &str) -> Result<Json> {
    core.lock().unwrap().roundtrip(
        name,
        Json::obj(vec![("op", Json::str("stats")), ("name", Json::str(name))]),
    )
}

fn resize(core: &Arc<Mutex<ClientCore>>, name: &str, width: u64) -> Result<u64> {
    let resp = core.lock().unwrap().roundtrip(
        name,
        Json::obj(vec![
            ("op", Json::str("resize")),
            ("name", Json::str(name)),
            ("width", Json::num(width as f64)),
        ]),
    )?;
    resp.get("width").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing width"))
}

fn set_policy(core: &Arc<Mutex<ClientCore>>, name: &str, policy: &str) -> Result<String> {
    let resp = core.lock().unwrap().roundtrip(
        name,
        Json::obj(vec![
            ("op", Json::str("policy")),
            ("name", Json::str(name)),
            ("policy", Json::str(policy)),
        ]),
    )?;
    resp.get("policy")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing policy"))
}

