//! The object registry: named fetch-and-add counters, funnel-backed
//! FIFO queues, and elimination-backed LIFO stacks living behind one
//! wire protocol.
//!
//! A registry maps names to [`ObjectEntry`]s. An entry is a
//! **counter** — an [`ElasticAggFunnel`] with a per-object
//! [`WidthPolicy`], today's ticket counter made nameable — a
//! **queue** — any [`crate::queue::make_queue`] spec, with
//! `lcrq+elastic` queues keeping an [`ElasticIndexFactory`] handle so
//! the service's resize controller can walk a queue's ring indices
//! exactly like a counter's Aggregator set — or a **stack** — any
//! [`crate::queue::make_stack`] spec, whose elimination width is the
//! resizable knob. Every entry carries its own [`Metrics`] so `stats`
//! reports independent per-object traffic and contention counters.
//!
//! Lookups take a read lock and clone an `Arc` out; the data-plane ops
//! (`take`, `enqueue`, …) then run lock-free on the object itself.
//! `create`/`delete` are control-plane and take the write lock.
//!
//! **Byte payloads.** Queue payloads are [`Item`]s — integers or byte
//! strings — but the lock-free rings keep trading in small integers:
//! every enqueue interns its payload into the entry's [`ItemTable`]
//! and enqueues the table index; dequeue pops the index and takes the
//! payload back out. The indirection costs one striped-lock hop per
//! op far off the rings' CAS hot path, and leaves the ring/funnel
//! layer's word-sized item representation untouched.
//!
//! **Journaling hook.** When the service runs with a `data_dir`, the
//! registry is handed its shard's [`ShardLog`] before any object is
//! created. From then on every persisted entry carries a [`Journal`]
//! and the registry records *logical* effects — `create`/`delete`
//! specs, post-batch counter values, queue item deltas — never funnel
//! internals. Per-object `persist = false` opts out. Create/delete
//! records are appended while the registry write lock is held, so the
//! WAL's control-plane order always matches the map's.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use anyhow::{anyhow, Result};

use super::error::{service_err, ErrorCode};

use super::frame::{Item, MAX_ITEM_BYTES};
use super::metrics::Metrics;
use super::persist::{Journal, Record, ShardLog};
use crate::config::ObjectManifest;
use crate::faa::backend::DirectPermits;
use crate::faa::{backend, BackendSpec, BatchStats, ElasticAggFunnel, FetchAddObject, WidthPolicy};
use crate::queue::{
    make_queue_with_handle, make_stack, ConcurrentQueue, ConcurrentStack, ElasticIndexFactory,
    EMPTY_ITEM, PRQ_MAX_ITEM,
};
use crate::sync::{CasCtl, RetryPolicy, SpinLock};
use crate::util::json::Json;

/// The object un-named requests route to (the pre-registry protocol's
/// single anonymous ticket counter, now just a well-known name).
pub const DEFAULT_OBJECT: &str = "tickets";

/// Per-object creation options beyond the backend spec string.
#[derive(Clone, Copy, Debug)]
pub struct CreateOpts {
    /// Elastic slot capacity override.
    pub max_width: Option<usize>,
    /// §4.4 direct-thread quota `d`: at most this many `priority`
    /// requests ride `Fetch&AddDirect` concurrently; the rest are
    /// demoted to the funnel path. `None` = unlimited (every priority
    /// request goes direct). Counters only. Overrides a `:d<k>`
    /// segment in the backend spec.
    pub direct_quota: Option<usize>,
    /// Whether the object participates in the durability layer when
    /// the service runs with a `data_dir` (default). `false` makes
    /// the object ephemeral: it vanishes on restart.
    pub persist: bool,
}

impl Default for CreateOpts {
    fn default() -> Self {
        Self { max_width: None, direct_quota: None, persist: true }
    }
}

impl CreateOpts {
    /// Only a width override (the historical `create` option set).
    pub fn width(max_width: Option<usize>) -> Self {
        Self { max_width, ..Self::default() }
    }
}

/// Stripes in an [`ItemTable`]; indices hash by `idx % STRIPES`, so
/// consecutive enqueues land on different locks.
const TABLE_STRIPES: usize = 8;

/// The payload table behind a queue: rings enqueue/dequeue small
/// sequential indices while the [`Item`]s themselves live here. The
/// counter never recycles, so an index uniquely names one payload for
/// the object's lifetime (2⁶⁴ enqueues outlives any deployment, and
/// stays far below both the ring sentinel and PRQ's 48-bit bound for
/// any reachable table size).
struct ItemTable {
    next: AtomicU64,
    stripes: [SpinLock<HashMap<u64, Item>>; TABLE_STRIPES],
}

impl ItemTable {
    fn new() -> ItemTable {
        ItemTable {
            next: AtomicU64::new(0),
            stripes: std::array::from_fn(|_| SpinLock::new(HashMap::new())),
        }
    }

    /// Store `item` and return the ring index that names it.
    fn intern(&self, item: Item) -> u64 {
        let idx = self.next.fetch_add(1, Ordering::Relaxed);
        self.stripes[(idx as usize) % TABLE_STRIPES].lock().insert(idx, item);
        idx
    }

    /// Remove and return the payload a dequeued ring index names.
    fn take(&self, idx: u64) -> Option<Item> {
        self.stripes[(idx as usize) % TABLE_STRIPES].lock().remove(&idx)
    }
}

/// A served object's body.
pub enum ObjectBody {
    Counter(ElasticAggFunnel),
    Queue {
        queue: Arc<dyn ConcurrentQueue>,
        /// Present iff the index backend is elastic (resizable).
        elastic: Option<ElasticIndexFactory>,
    },
    Stack {
        stack: Arc<dyn ConcurrentStack>,
        /// Whether `resize` may change the elimination width.
        resizable: bool,
    },
}

/// One named object: body + backend label + per-object metrics +
/// runtime-swappable width policy (+ a durability journal when the
/// registry persists).
pub struct ObjectEntry {
    pub name: String,
    /// Canonical backend spec (re-parseable; shown by `list`).
    pub backend: String,
    pub metrics: Metrics,
    policy: Mutex<WidthPolicy>,
    /// §4.4 direct-thread quota gate; `None` = unlimited direct. The
    /// entry gates here (rather than wrapping the funnel in a
    /// [`backend::DirectQuota`]) so demotions are visible in the
    /// per-object metrics.
    direct: Option<DirectPermits>,
    /// Create-time elastic capacity override; journaled so recovery
    /// can re-create the object exactly (the backend label does not
    /// carry it).
    max_width_override: Option<usize>,
    /// Largest enqueuable *integer* item (queues). The ring itself now
    /// carries table indices, but the integer-payload bound keeps the
    /// wire contract each family always had: PRQ rejects beyond 48
    /// bits, persisted queues reject beyond the JSON-exact range.
    item_max: u64,
    /// Payload table (queues): ring indices in, [`Item`]s out.
    table: ItemTable,
    /// Durability hook; present iff this entry persists.
    journal: Option<Journal>,
    body: ObjectBody,
}

impl ObjectEntry {
    pub fn kind(&self) -> &'static str {
        match self.body {
            ObjectBody::Counter(_) => "counter",
            ObjectBody::Queue { .. } => "queue",
            ObjectBody::Stack { .. } => "stack",
        }
    }

    fn wrong_kind(&self, op: &str, wanted: &str) -> anyhow::Error {
        service_err(
            ErrorCode::WrongKind,
            format!("object {:?} is a {}; {op} needs a {wanted}", self.name, self.kind()),
        )
    }

    fn as_counter(&self, op: &str) -> Result<&ElasticAggFunnel> {
        match &self.body {
            ObjectBody::Counter(f) => Ok(f),
            _ => Err(self.wrong_kind(op, "counter")),
        }
    }

    fn as_queue(&self, op: &str) -> Result<&Arc<dyn ConcurrentQueue>> {
        match &self.body {
            ObjectBody::Queue { queue, .. } => Ok(queue),
            _ => Err(self.wrong_kind(op, "queue")),
        }
    }

    fn as_stack(&self, op: &str) -> Result<&Arc<dyn ConcurrentStack>> {
        match &self.body {
            ObjectBody::Stack { stack, .. } => Ok(stack),
            _ => Err(self.wrong_kind(op, "stack")),
        }
    }

    /// Counter op: `Fetch&Add(count)`; `priority` requests take the
    /// §4.4 `Fetch&AddDirect` fast path while the object's
    /// direct-thread quota has a free slot, and are demoted to the
    /// funnel (counted as `take_priority_demoted`) when it does not.
    pub fn take(&self, tid: usize, count: u64, priority: bool) -> Result<u64> {
        let funnel = self.as_counter("take")?;
        let start = if priority {
            match &self.direct {
                None => {
                    self.metrics.incr("take_priority");
                    funnel.fetch_add_direct(tid, count as i64)
                }
                Some(gate) if gate.try_acquire() => {
                    self.metrics.incr("take_priority");
                    let v = funnel.fetch_add_direct(tid, count as i64);
                    gate.release();
                    v
                }
                Some(_) => {
                    // Quota exhausted: priority demotes to the shared
                    // funnel path instead of overloading `Main`.
                    self.metrics.incr("take_priority_demoted");
                    funnel.fetch_add(tid, count as i64)
                }
            }
        } else {
            self.metrics.incr("take");
            funnel.fetch_add(tid, count as i64)
        };
        if let Some(journal) = &self.journal {
            // The logical effect, not the funnel state: the counter
            // reached at least `start + count` (replay keeps the max
            // over all records, so out-of-order appends are safe).
            // A persisted counter's grants must stay in the
            // JSON-exact range — beyond it the journaled value would
            // round and a restart could re-issue acked tickets. The
            // range is consumed in memory either way, but it is
            // *not* acked and *not* journaled, so recovery stays
            // exact and a later snapshot can never brick the boot.
            let end = start
                .checked_add(count)
                .filter(|e| *e <= super::persist::MAX_DURABLE_ITEM);
            let Some(end) = end else {
                self.metrics.incr("take_beyond_durable");
                return Err(service_err(
                    ErrorCode::QuotaExceeded,
                    format!("counter {:?} exhausted its durable range (2^53)", self.name),
                ));
            };
            journal.record_counter(end);
        }
        Ok(start)
    }

    /// The configured §4.4 direct quota (`None` = unlimited).
    pub fn direct_quota(&self) -> Option<usize> {
        self.direct.as_ref().map(DirectPermits::quota)
    }

    /// Counter op: linearizable read.
    pub fn read(&self, tid: usize) -> Result<u64> {
        let funnel = self.as_counter("read")?;
        self.metrics.incr("read");
        Ok(funnel.read(tid))
    }

    /// Validate a payload against this queue's bounds. Integer items
    /// keep the bound their family always had (PRQ's 48 bits, the
    /// durable 2⁵³ range, the ring sentinel); byte items are bounded
    /// by [`MAX_ITEM_BYTES`].
    pub(super) fn validate_item(&self, item: &Item) -> Result<()> {
        match item {
            Item::Int(v) => {
                if *v >= EMPTY_ITEM {
                    return Err(service_err(
                        ErrorCode::ItemTooLarge,
                        format!("item {v} is reserved"),
                    ));
                }
                if *v > self.item_max {
                    // PRQ packs values into 48 bits; reject cleanly
                    // instead of letting the queue's debug assertion
                    // kill the connection handler.
                    return Err(service_err(
                        ErrorCode::ItemTooLarge,
                        format!(
                            "item {v} exceeds queue {:?}'s item bound {}",
                            self.name, self.item_max
                        ),
                    ));
                }
            }
            Item::Bytes(b) => {
                if b.len() > MAX_ITEM_BYTES {
                    return Err(service_err(
                        ErrorCode::ItemTooLarge,
                        format!(
                            "byte item of {} bytes exceeds queue {:?}'s limit {MAX_ITEM_BYTES}",
                            b.len(),
                            self.name
                        ),
                    ));
                }
            }
        }
        Ok(())
    }

    /// Queue op: enqueue one payload (integer or byte string). The
    /// payload is interned in the item table and the ring carries its
    /// index.
    pub fn enqueue_item(&self, tid: usize, item: Item) -> Result<()> {
        let queue = self.as_queue("enqueue")?;
        self.validate_item(&item)?;
        self.metrics.incr("enqueue");
        // Journal write-ahead: the Enq record must be ordered before
        // any Deq record for this item, and a dequeuer can only see
        // the item after `queue.enqueue` below — so recording first
        // guarantees replay never sees a dequeue of an item whose
        // enqueue record is still in flight. (A crash in between
        // leaves an unacked item in the durable state: at-least-once,
        // never lost.)
        if let Some(journal) = &self.journal {
            journal.record_enqueue(item.clone());
        }
        let idx = self.table.intern(item);
        queue.enqueue(tid, idx);
        Ok(())
    }

    /// Queue op: enqueue one integer item (the historical API).
    pub fn enqueue(&self, tid: usize, item: u64) -> Result<()> {
        self.enqueue_item(tid, Item::Int(item))
    }

    /// Queue op: dequeue the oldest payload (`None` on empty).
    pub fn dequeue_item(&self, tid: usize) -> Result<Option<Item>> {
        let queue = self.as_queue("dequeue")?;
        self.metrics.incr("dequeue");
        match queue.dequeue(tid) {
            Some(idx) => {
                // Every ring value was interned by enqueue/seed, so
                // the table always holds the index; fall back to the
                // raw index rather than poisoning an executor if that
                // invariant ever breaks.
                let item = self.table.take(idx).unwrap_or(Item::Int(idx));
                if let Some(journal) = &self.journal {
                    journal.record_dequeue(item.clone());
                }
                Ok(Some(item))
            }
            None => {
                self.metrics.incr("dequeue_empty");
                Ok(None)
            }
        }
    }

    /// Stack op: push one payload (integer or byte string). Same
    /// item-table indirection and write-ahead contract as
    /// [`ObjectEntry::enqueue_item`]: the Psh record lands before the
    /// item is visible to any popper, so replay never sees a pop of an
    /// item whose push record is still in flight.
    pub fn push_item(&self, tid: usize, item: Item) -> Result<()> {
        let stack = self.as_stack("push")?;
        self.validate_item(&item)?;
        self.metrics.incr("push");
        if let Some(journal) = &self.journal {
            journal.record_push(item.clone());
        }
        let idx = self.table.intern(item);
        stack.push(tid, idx);
        Ok(())
    }

    /// Stack op: push one integer item.
    pub fn push(&self, tid: usize, item: u64) -> Result<()> {
        self.push_item(tid, Item::Int(item))
    }

    /// Stack op: pop the most recently pushed payload (`None` on
    /// empty).
    pub fn pop_item(&self, tid: usize) -> Result<Option<Item>> {
        let stack = self.as_stack("pop")?;
        self.metrics.incr("pop");
        match stack.pop(tid) {
            Some(idx) => {
                let item = self.table.take(idx).unwrap_or(Item::Int(idx));
                if let Some(journal) = &self.journal {
                    journal.record_pop(item.clone());
                }
                Ok(Some(item))
            }
            None => {
                self.metrics.incr("pop_empty");
                Ok(None)
            }
        }
    }

    // -----------------------------------------------------------------
    // Coalesced (merged) entry points — the executor-level coalescer's
    // batch seam. Each absorbs an entire sweep group in ONE backend
    // operation (one hardware-FAA-backed funnel op, one journal batch
    // record) while accounting per-request metrics so `stats` stays
    // comparable with the unmerged path.
    // -----------------------------------------------------------------

    /// Coalesced counter op: `reqs` pending takes totalling `total`
    /// ride one `Fetch&Add(total)`; the caller slices
    /// `[start, start+total)` back per request (dense, disjoint, in
    /// pending order). All members share one `priority` flag — the
    /// coalescer never merges across priority classes, so the §4.4
    /// gate is acquired once for the whole batch.
    pub fn take_merged(&self, tid: usize, total: u64, reqs: u64, priority: bool) -> Result<u64> {
        let funnel = self.as_counter("take")?;
        let start = if priority {
            match &self.direct {
                None => {
                    self.metrics.add("take_priority", reqs);
                    funnel.fetch_add_direct(tid, total as i64)
                }
                Some(gate) if gate.try_acquire() => {
                    self.metrics.add("take_priority", reqs);
                    let v = funnel.fetch_add_direct(tid, total as i64);
                    gate.release();
                    v
                }
                Some(_) => {
                    self.metrics.add("take_priority_demoted", reqs);
                    funnel.fetch_add(tid, total as i64)
                }
            }
        } else {
            self.metrics.add("take", reqs);
            funnel.fetch_add(tid, total as i64)
        };
        if let Some(journal) = &self.journal {
            // One durable-range check and one record for the whole
            // merged grant (same contract as the per-op path: beyond
            // 2^53 nothing is acked or journaled).
            let end = start
                .checked_add(total)
                .filter(|e| *e <= super::persist::MAX_DURABLE_ITEM);
            let Some(end) = end else {
                self.metrics.add("take_beyond_durable", reqs);
                return Err(service_err(
                    ErrorCode::QuotaExceeded,
                    format!("counter {:?} exhausted its durable range (2^53)", self.name),
                ));
            };
            journal.record_counter(end);
        }
        Ok(start)
    }

    /// Coalesced counter read: `reqs` pending reads share one
    /// linearizable `read` — every member sees the same value, which
    /// is a legal linearization (all at the same point).
    pub fn read_merged(&self, tid: usize, reqs: u64) -> Result<u64> {
        let funnel = self.as_counter("read")?;
        self.metrics.add("read", reqs);
        Ok(funnel.read(tid))
    }

    /// Coalesced queue insert: the concatenated item lists of a whole
    /// sweep group, journaled write-ahead as ONE batch record, then
    /// interned and enqueued in order. Items are pre-validated by the
    /// coalescer (an invalid item makes its request a passthrough so
    /// its error reply stays byte-identical); re-validating here keeps
    /// the entry point safe for any caller.
    pub fn enqueue_merged(&self, tid: usize, items: Vec<Item>) -> Result<()> {
        let queue = self.as_queue("enqueue")?;
        for item in &items {
            self.validate_item(item)?;
        }
        self.metrics.add("enqueue", items.len() as u64);
        if let Some(journal) = &self.journal {
            journal.record_add_batch(items.clone());
        }
        for item in items {
            let idx = self.table.intern(item);
            queue.enqueue(tid, idx);
        }
        Ok(())
    }

    /// Coalesced stack insert; mirrors [`ObjectEntry::enqueue_merged`]
    /// (write-ahead batch record, then push in order — replay of a
    /// `Psh` record rebuilds bottom-to-top).
    pub fn push_merged(&self, tid: usize, items: Vec<Item>) -> Result<()> {
        let stack = self.as_stack("push")?;
        for item in &items {
            self.validate_item(item)?;
        }
        self.metrics.add("push", items.len() as u64);
        if let Some(journal) = &self.journal {
            journal.record_add_batch(items.clone());
        }
        for item in items {
            let idx = self.table.intern(item);
            stack.push(tid, idx);
        }
        Ok(())
    }

    /// Coalesced queue remove: up to `want` dequeues (a whole sweep
    /// group's total), stopping at empty, journaled as ONE batch
    /// record. The caller deals the items back per request in pending
    /// order — FIFO is preserved because the dequeues happen here, in
    /// order, under one executor.
    pub fn dequeue_merged(&self, tid: usize, want: u64) -> Result<Vec<Item>> {
        let queue = self.as_queue("dequeue")?;
        let mut out = Vec::with_capacity(want.min(64) as usize);
        for _ in 0..want {
            self.metrics.incr("dequeue");
            match queue.dequeue(tid) {
                Some(idx) => out.push(self.table.take(idx).unwrap_or(Item::Int(idx))),
                None => {
                    self.metrics.incr("dequeue_empty");
                    break;
                }
            }
        }
        if !out.is_empty() {
            if let Some(journal) = &self.journal {
                journal.record_remove_batch(out.clone());
            }
        }
        Ok(out)
    }

    /// Coalesced stack remove; mirrors [`ObjectEntry::dequeue_merged`].
    pub fn pop_merged(&self, tid: usize, want: u64) -> Result<Vec<Item>> {
        let stack = self.as_stack("pop")?;
        let mut out = Vec::with_capacity(want.min(64) as usize);
        for _ in 0..want {
            self.metrics.incr("pop");
            match stack.pop(tid) {
                Some(idx) => out.push(self.table.take(idx).unwrap_or(Item::Int(idx))),
                None => {
                    self.metrics.incr("pop_empty");
                    break;
                }
            }
        }
        if !out.is_empty() {
            if let Some(journal) = &self.journal {
                journal.record_remove_batch(out.clone());
            }
        }
        Ok(out)
    }

    /// Recovery-only: raise a counter to its recovered value without
    /// journaling (the value is already in the recovered model). Uses
    /// the reserved in-process tid 0 — boot is single-threaded.
    pub(super) fn seed_counter(&self, value: u64) -> Result<()> {
        let funnel = self.as_counter("seed")?;
        // A recovered value beyond the JSON-exact range cannot be
        // trusted (and would wrap the i64 delta below at 2^63):
        // refuse rather than seed a wrong counter.
        if value > super::persist::MAX_DURABLE_ITEM {
            return Err(anyhow!(
                "recovered counter value {value} exceeds the durable range"
            ));
        }
        if value > 0 {
            funnel.fetch_add_direct(0, value as i64);
        }
        Ok(())
    }

    /// Recovery-only: re-enqueue a recovered payload without
    /// journaling (it is already in the recovered model).
    pub(super) fn seed_queue_item(&self, item: Item) -> Result<()> {
        let queue = self.as_queue("seed")?;
        let idx = self.table.intern(item);
        queue.enqueue(0, idx);
        Ok(())
    }

    /// Recovery-only: re-push a recovered payload without journaling.
    /// The recovered item list is bottom-to-top, so seeding in order
    /// rebuilds the same stack.
    pub(super) fn seed_stack_item(&self, item: Item) -> Result<()> {
        let stack = self.as_stack("seed")?;
        let idx = self.table.intern(item);
        stack.push(0, idx);
        Ok(())
    }

    /// The durability journal, when this entry persists.
    pub(crate) fn journal(&self) -> Option<&Journal> {
        self.journal.as_ref()
    }

    /// Whether this entry participates in the durability layer.
    pub fn persisted(&self) -> bool {
        self.journal.is_some()
    }

    /// Set the active funnel width: the Aggregator prefix for a
    /// counter, every live ring index for an elastic-index queue, the
    /// elimination-array width for an elastic stack.
    /// Returns `(new_width, previous_width)`.
    pub fn resize(&self, width: usize) -> Result<(usize, usize)> {
        self.metrics.incr("resize");
        match &self.body {
            ObjectBody::Counter(f) => {
                let previous = f.resize(width);
                Ok((f.active_width(), previous))
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                let previous = factory.active_width();
                Ok((factory.resize(width), previous))
            }
            ObjectBody::Queue { .. } => {
                Err(anyhow!("queue {:?} has a non-resizable {:?} index", self.name, self.backend))
            }
            ObjectBody::Stack { stack, resizable: true } => {
                let previous = stack.elimination_width();
                Ok((stack.resize_elimination(width), previous))
            }
            ObjectBody::Stack { .. } => Err(anyhow!(
                "stack {:?} has a non-resizable {:?} elimination layer",
                self.name,
                self.backend
            )),
        }
    }

    /// Swap the CAS retry policy at runtime: the counter funnel's (or
    /// queue's) hot-loop pacing plus the §4.4 direct-quota gate's.
    pub fn set_cas_policy(&self, policy: RetryPolicy) {
        self.metrics.incr("cas_policy");
        match &self.body {
            ObjectBody::Counter(f) => f.set_cas_policy(policy),
            ObjectBody::Queue { queue, .. } => queue.set_cas_policy(policy),
            ObjectBody::Stack { stack, .. } => stack.set_cas_policy(policy),
        }
        if let Some(gate) = &self.direct {
            gate.set_cas_policy(policy);
        }
    }

    /// The CAS retry policy in force (`None` for backends with no
    /// paced CAS loop, e.g. `msq` or `lcrq+hw` queues).
    pub fn cas_policy(&self) -> Option<RetryPolicy> {
        match &self.body {
            ObjectBody::Counter(f) => f.cas_policy(),
            ObjectBody::Queue { queue, .. } => queue.cas_policy(),
            ObjectBody::Stack { stack, .. } => stack.cas_policy(),
        }
    }

    /// Swap the width policy at runtime; applies once immediately.
    /// Returns the active width now in force.
    pub fn set_policy(&self, policy: WidthPolicy) -> Result<usize> {
        self.metrics.incr("policy");
        match &self.body {
            ObjectBody::Counter(f) => {
                *self.policy.lock().unwrap() = policy;
                Ok(f.poll_policy(&policy))
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                *self.policy.lock().unwrap() = policy;
                // Through the factory so future rings' cells are built
                // under the new policy too.
                Ok(factory.set_policy(policy))
            }
            ObjectBody::Queue { .. } => {
                Err(anyhow!("queue {:?} has a non-resizable {:?} index", self.name, self.backend))
            }
            ObjectBody::Stack { stack, resizable: true } => {
                *self.policy.lock().unwrap() = policy;
                // Stacks have no contention window yet, so a policy
                // swap applies its initial width once; the controller
                // tick (`poll`) leaves stacks alone.
                let w = policy.initial_width(stack.max_threads(), usize::MAX).max(1);
                Ok(stack.resize_elimination(w))
            }
            ObjectBody::Stack { .. } => Err(anyhow!(
                "stack {:?} has a non-resizable {:?} elimination layer",
                self.name,
                self.backend
            )),
        }
    }

    /// The current width policy.
    pub fn policy(&self) -> WidthPolicy {
        *self.policy.lock().unwrap()
    }

    /// One resize-controller tick: apply the object's policy to its
    /// contention window. No-op for non-elastic queues.
    pub fn poll(&self) {
        let policy = self.policy();
        match &self.body {
            ObjectBody::Counter(f) => {
                f.poll_policy(&policy);
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                factory.poll_policy(&policy);
            }
            ObjectBody::Queue { .. } | ObjectBody::Stack { .. } => {}
        }
    }

    /// The object's combining statistics (counter funnel, or queue
    /// ring indices for batching index backends).
    pub fn batch_stats(&self) -> BatchStats {
        match &self.body {
            ObjectBody::Counter(f) => f.batch_stats(),
            ObjectBody::Queue { queue, .. } => queue.batch_stats(),
            ObjectBody::Stack { stack, .. } => stack.batch_stats(),
        }
    }

    /// Per-object `stats` payload: identity, per-object traffic
    /// counters, and independent width/contention counters.
    pub fn stats_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert("name".to_string(), Json::str(self.name.clone()));
        obj.insert("kind".to_string(), Json::str(self.kind()));
        obj.insert("backend".to_string(), Json::str(self.backend.clone()));
        obj.insert("persist".to_string(), Json::Bool(self.journal.is_some()));
        for (k, v) in self.metrics.snapshot() {
            obj.insert(k, Json::num(v as f64));
        }
        let stats = self.batch_stats();
        for (k, v) in [
            ("main_faas", stats.main_faas),
            ("batched_ops", stats.ops),
            ("single_op_batches", stats.single_op_batches),
            ("cas_failures", stats.cas_failures),
        ] {
            obj.insert(k.to_string(), Json::num(v as f64));
        }
        obj.insert("avg_batch".to_string(), Json::num(stats.avg_batch_size()));
        if let Some(p) = self.cas_policy() {
            obj.insert("cas_policy".to_string(), Json::str(p.label()));
        }
        match &self.body {
            ObjectBody::Counter(f) => {
                obj.insert("active_width".to_string(), Json::num(f.active_width() as f64));
                obj.insert("max_width".to_string(), Json::num(f.max_width() as f64));
                obj.insert("resizes".to_string(), Json::num(f.resizes() as f64));
                obj.insert("width_policy".to_string(), Json::str(self.policy().label()));
                if let Some(d) = self.direct_quota() {
                    obj.insert("direct_quota".to_string(), Json::num(d as f64));
                }
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                obj.insert("active_width".to_string(), Json::num(factory.active_width() as f64));
                obj.insert("max_width".to_string(), Json::num(factory.max_width() as f64));
                obj.insert("index_cells".to_string(), Json::num(factory.live_cells() as f64));
                obj.insert("width_policy".to_string(), Json::str(self.policy().label()));
            }
            ObjectBody::Queue { .. } => {}
            ObjectBody::Stack { stack, resizable } => {
                if stack.elimination_width() > 0 || *resizable {
                    obj.insert(
                        "active_width".to_string(),
                        Json::num(stack.elimination_width() as f64),
                    );
                }
            }
        }
        Json::Obj(obj)
    }
}

/// The concurrent name → object map.
pub struct Registry {
    map: RwLock<BTreeMap<String, Arc<ObjectEntry>>>,
    /// Funnel tid bound every created object is built for (the
    /// service's lease-pool size plus the foreign pool and the
    /// reserved tid 0).
    max_threads: usize,
    /// The shard's durability log; set once before the first create
    /// when the service runs with a `data_dir`.
    log: OnceLock<Arc<ShardLog>>,
    /// Service-wide default CAS retry policy: applied to every new
    /// object whose backend spec carries no `:b<policy>` suffix.
    default_cas: CasCtl,
}

impl Registry {
    pub fn new(max_threads: usize) -> Self {
        Self {
            map: RwLock::new(BTreeMap::new()),
            max_threads: max_threads.max(1),
            log: OnceLock::new(),
            default_cas: CasCtl::new(RetryPolicy::default()),
        }
    }

    /// Set the default CAS retry policy new objects are built with.
    /// Spec-level `:b<policy>` suffixes win over this; already-created
    /// objects are untouched (swap those with the `policy` wire op).
    pub fn set_default_cas_policy(&self, policy: RetryPolicy) {
        self.default_cas.set(policy);
    }

    /// The default CAS retry policy for new objects.
    pub fn default_cas_policy(&self) -> RetryPolicy {
        self.default_cas.get()
    }

    /// Attach the shard's durability log. Must happen before any
    /// object is created; later calls are ignored.
    pub fn set_log(&self, log: Arc<ShardLog>) {
        let _ = self.log.set(log);
    }

    /// The attached durability log, if any.
    pub fn log(&self) -> Option<&Arc<ShardLog>> {
        self.log.get()
    }

    /// Build the journal a new entry should carry (`None` when the
    /// registry has no log or the object opted out).
    fn journal_for(&self, name: &str, kind: &str, persist: bool) -> Option<Journal> {
        if !persist {
            return None;
        }
        let log = self.log.get()?;
        Some(match kind {
            "counter" => Journal::counter(Arc::clone(log), name),
            "stack" => Journal::stack(Arc::clone(log), name),
            _ => Journal::queue(Arc::clone(log), name),
        })
    }

    /// Create a counter directly from a policy (the boot path for the
    /// default object, where the policy is already parsed). `initial`
    /// overrides the policy's starting width; `direct_quota` is the
    /// §4.4 `d` parameter (`None` = unlimited direct); `persist`
    /// opts the object into the durability layer when one is attached.
    pub fn create_counter(
        &self,
        name: &str,
        policy: WidthPolicy,
        max_width: usize,
        initial: Option<usize>,
        direct_quota: Option<usize>,
        cas: Option<RetryPolicy>,
        persist: bool,
    ) -> Result<Arc<ObjectEntry>> {
        let mut spec = BackendSpec::Elastic {
            policy,
            max_width: max_width.max(1),
            direct: None,
            cas: None,
        };
        if let Some(d) = direct_quota {
            spec = spec.with_direct_quota(d);
        }
        if let Some(p) = cas {
            spec = spec.with_cas_policy(p);
        }
        // An explicit `:b<policy>` stays visible in the canonical
        // label (so recovery re-creates it exactly); the service-wide
        // default applies silently and tracks later default changes
        // only for objects created after the change.
        let effective_cas = cas.unwrap_or_else(|| self.default_cas.get());
        let funnel = backend::build_elastic(self.max_threads, policy, max_width.max(1));
        funnel.set_cas_policy(effective_cas);
        if let Some(w) = initial {
            funnel.resize(w);
        }
        let name = validated_name(name)?;
        let journal = self.journal_for(&name, "counter", persist);
        self.insert(ObjectEntry {
            name,
            backend: spec.label(),
            metrics: Metrics::new(),
            policy: Mutex::new(policy),
            direct: direct_quota.map(|d| DirectPermits::with_policy(d, effective_cas)),
            // The backend label does not carry the elastic capacity,
            // so journal the effective one: recovery re-creates the
            // counter with exactly this ceiling.
            max_width_override: Some(max_width.max(1)),
            item_max: EMPTY_ITEM - 1,
            table: ItemTable::new(),
            journal,
            body: ObjectBody::Counter(funnel),
        })
    }

    /// Create an object from wire/manifest strings. An empty
    /// `backend_spec` takes the kind's default; [`CreateOpts`] carries
    /// the per-object overrides (elastic slot capacity, §4.4 direct
    /// quota).
    pub fn create(
        &self,
        name: &str,
        kind: &str,
        backend_spec: &str,
        opts: CreateOpts,
    ) -> Result<Arc<ObjectEntry>> {
        let backend_spec = if backend_spec.is_empty() {
            ObjectManifest::default_backend(kind).unwrap_or("")
        } else {
            backend_spec
        };
        match kind {
            "counter" => {
                let mut spec = BackendSpec::parse(backend_spec)
                    .ok_or_else(|| anyhow!("unknown counter backend {backend_spec:?}"))?;
                if let Some(w) = opts.max_width {
                    spec = spec.with_max_width(w);
                }
                // An explicit option wins over a `:d<k>` spec segment.
                if let Some(d) = opts.direct_quota {
                    spec = spec.with_direct_quota(d);
                }
                let (policy, width) = spec.counter_policy().ok_or_else(|| {
                    anyhow!(
                        "counter backend {backend_spec:?} does not batch; \
                         use aggfunnel:<m> or elastic:<policy>"
                    )
                })?;
                self.create_counter(
                    name,
                    policy,
                    width,
                    None,
                    spec.direct_quota(),
                    spec.cas_policy(),
                    opts.persist,
                )
            }
            "queue" => {
                if opts.direct_quota.is_some() {
                    return Err(anyhow!(
                        "direct_quota applies to counters; queue {name:?} has no priority path"
                    ));
                }
                // A `:d<k>` segment on the index spec would be
                // silently inert (ring indices have no priority
                // path), so reject it like the explicit option
                // instead of echoing a quota that isn't enforced.
                let index_spec = backend_spec.split_once('+').map(|(_, index)| index);
                if index_spec
                    .and_then(BackendSpec::parse)
                    .and_then(|s| s.direct_quota())
                    .is_some()
                {
                    return Err(anyhow!(
                        "direct quota applies to counters; queue index spec {backend_spec:?} \
                         cannot carry :d<k>"
                    ));
                }
                let (queue, elastic) =
                    make_queue_with_handle(backend_spec, self.max_threads, opts.max_width)
                        .ok_or_else(|| anyhow!("unknown queue backend {backend_spec:?}"))?;
                // `make_queue_with_handle` already applied any spec
                // `:b<policy>` suffix; without one the service-wide
                // default takes over (a no-op for queue families with
                // no paced CAS loop).
                if index_spec
                    .and_then(BackendSpec::parse)
                    .and_then(|s| s.cas_policy())
                    .is_none()
                {
                    queue.set_cas_policy(self.default_cas.get());
                }
                let policy = match index_spec.and_then(BackendSpec::parse) {
                    Some(BackendSpec::Elastic { policy, .. }) => policy,
                    _ => WidthPolicy::Fixed(backend::DEFAULT_AGGREGATORS),
                };
                let family = backend_spec.split_once('+').map_or(backend_spec, |(f, _)| f);
                let mut item_max = if matches!(family.trim(), "prq" | "lprq") {
                    PRQ_MAX_ITEM
                } else {
                    EMPTY_ITEM - 1
                };
                let name = validated_name(name)?;
                let journal = self.journal_for(&name, "queue", opts.persist);
                if journal.is_some() {
                    // Durable items ride the JSON snapshot/WAL model:
                    // cap at the largest exactly-representable value
                    // so recovery can never round an acked item.
                    item_max = item_max.min(super::persist::MAX_DURABLE_ITEM);
                }
                self.insert(ObjectEntry {
                    name,
                    backend: backend_spec.trim().to_string(),
                    metrics: Metrics::new(),
                    policy: Mutex::new(policy),
                    direct: None,
                    max_width_override: opts.max_width,
                    item_max,
                    table: ItemTable::new(),
                    journal,
                    body: ObjectBody::Queue { queue, elastic },
                })
            }
            "stack" => {
                if opts.direct_quota.is_some() {
                    return Err(anyhow!(
                        "direct_quota applies to counters; stack {name:?} has no priority path"
                    ));
                }
                // `make_stack` already rejects `:d<k>` layer segments
                // (stacks have no priority path), so a bad spec falls
                // through to the unknown-backend error below.
                let stack = make_stack(backend_spec, self.max_threads, opts.max_width)
                    .ok_or_else(|| anyhow!("unknown stack backend {backend_spec:?}"))?;
                let layer_spec = backend_spec.split_once('+').map(|(_, layer)| layer);
                let parsed_layer = layer_spec.and_then(BackendSpec::parse);
                if parsed_layer.as_ref().and_then(|s| s.cas_policy()).is_none() {
                    stack.set_cas_policy(self.default_cas.get());
                }
                let (policy, resizable) = match parsed_layer {
                    Some(BackendSpec::Elastic { policy, .. }) => (policy, true),
                    _ => (WidthPolicy::Fixed(backend::DEFAULT_AGGREGATORS), false),
                };
                let name = validated_name(name)?;
                let journal = self.journal_for(&name, "stack", opts.persist);
                let mut item_max = EMPTY_ITEM - 1;
                if journal.is_some() {
                    item_max = item_max.min(super::persist::MAX_DURABLE_ITEM);
                }
                self.insert(ObjectEntry {
                    name,
                    backend: backend_spec.trim().to_string(),
                    metrics: Metrics::new(),
                    policy: Mutex::new(policy),
                    direct: None,
                    max_width_override: opts.max_width,
                    item_max,
                    table: ItemTable::new(),
                    journal,
                    body: ObjectBody::Stack { stack, resizable },
                })
            }
            other => Err(anyhow!("unknown object kind {other:?} (counter | queue | stack)")),
        }
    }

    fn insert(&self, entry: ObjectEntry) -> Result<Arc<ObjectEntry>> {
        let mut map = self.map.write().unwrap();
        if map.contains_key(&entry.name) {
            return Err(anyhow!("object {:?} already exists", entry.name));
        }
        let entry = Arc::new(entry);
        map.insert(entry.name.clone(), Arc::clone(&entry));
        // Journal the creation while the write lock is held so WAL
        // control-plane order matches map order (a racing delete of
        // this name cannot journal before us). Replay-tolerant: a
        // Create for a name the model already holds is a no-op.
        if let Some(journal) = &entry.journal {
            journal.log().append_infallible(&[Record::Create {
                name: entry.name.clone(),
                kind: entry.kind().to_string(),
                backend: entry.backend.clone(),
                max_width: entry.max_width_override,
            }]);
        }
        Ok(entry)
    }

    /// Look an object up by name.
    pub fn get(&self, name: &str) -> Result<Arc<ObjectEntry>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| {
                service_err(ErrorCode::NoSuchObject, format!("no object named {name:?}"))
            })
    }

    /// Delete an object. In-flight data-plane ops on other
    /// connections hold their own `Arc` and finish normally.
    pub fn remove(&self, name: &str) -> Result<()> {
        let mut map = self.map.write().unwrap();
        let entry = map.remove(name).ok_or_else(|| {
            service_err(ErrorCode::NoSuchObject, format!("no object named {name:?}"))
        })?;
        if let Some(journal) = &entry.journal {
            // Retire before journaling the delete: a data-plane op
            // still running on a held Arc keeps working in memory but
            // can no longer journal into a re-created object of the
            // same name.
            journal.retire();
            journal.log().append_infallible(&[Record::Delete { name: name.to_string() }]);
        }
        Ok(())
    }

    /// Every registered object, in name order.
    pub fn list(&self) -> Vec<Arc<ObjectEntry>> {
        self.map.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.read().unwrap().is_empty()
    }
}

/// Object names share the config-key charset, so every valid name is
/// also addressable from an `[objects.<name>]` manifest section.
fn validated_name(name: &str) -> Result<String> {
    if name.is_empty() || name.len() > 64 {
        return Err(anyhow!("object names must be 1..=64 characters"));
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
        return Err(anyhow!("invalid object name {name:?} (use [A-Za-z0-9_-])"));
    }
    Ok(name.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plain() -> CreateOpts {
        CreateOpts::default()
    }

    #[test]
    fn empty_backend_defaults_per_kind() {
        let r = Registry::new(2);
        let c = r.create("c", "counter", "", plain()).unwrap();
        assert_eq!(c.backend, "elastic:aimd");
        let q = r.create("q", "queue", "", plain()).unwrap();
        assert_eq!(q.backend, "lcrq+elastic");
        q.enqueue(0, 1).unwrap();
        assert_eq!(q.dequeue_item(1).unwrap(), Some(Item::Int(1)));
        let s = r.create("s", "stack", "", plain()).unwrap();
        assert_eq!(s.backend, "stack+elastic");
        s.push(0, 2).unwrap();
        assert_eq!(s.pop_item(1).unwrap(), Some(Item::Int(2)));
        assert!(r.create("x", "heap", "", plain()).is_err(), "kind still validated");
    }

    #[test]
    fn create_get_list_delete() {
        let r = Registry::new(4);
        r.create("c1", "counter", "elastic:aimd", plain()).unwrap();
        r.create("q1", "queue", "lcrq+elastic", plain()).unwrap();
        assert_eq!(r.len(), 2);
        assert!(!r.is_empty());
        assert!(r.create("c1", "counter", "elastic:aimd", plain()).is_err(), "duplicate");
        let names: Vec<String> = r.list().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["c1", "q1"], "name order");
        assert_eq!(r.get("c1").unwrap().kind(), "counter");
        assert_eq!(r.get("q1").unwrap().kind(), "queue");
        r.remove("c1").unwrap();
        assert!(r.get("c1").is_err());
        assert!(r.remove("c1").is_err());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn list_is_sorted_regardless_of_creation_order() {
        let r = Registry::new(2);
        for name in ["zeta", "alpha", "mid", "beta"] {
            r.create(name, "counter", "elastic:aimd", plain()).unwrap();
        }
        let names: Vec<String> = r.list().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["alpha", "beta", "mid", "zeta"]);
    }

    #[test]
    fn invalid_specs_rejected() {
        let r = Registry::new(2);
        assert!(r.create("x", "counter", "bogus", plain()).is_err());
        assert!(r.create("x", "counter", "hw", plain()).is_err(), "hw counters have no width");
        assert!(r.create("x", "queue", "bogus", plain()).is_err());
        assert!(r.create("x", "stack", "lcrq", plain()).is_err());
        assert!(r.create("", "counter", "elastic", plain()).is_err());
        assert!(r.create("a b", "counter", "elastic", plain()).is_err());
        assert!(r.create(&"n".repeat(65), "counter", "elastic", plain()).is_err());
        // Queues have no priority path, so no direct quota either —
        // neither as an explicit option nor as a spec segment.
        let opts = CreateOpts { direct_quota: Some(1), ..CreateOpts::default() };
        assert!(r.create("x", "queue", "lcrq+elastic", opts).is_err());
        assert!(r.create("x", "queue", "lcrq+elastic:aimd:d2", plain()).is_err());
        assert!(r.create("x", "queue", "lcrq+aggfunnel:4:d1", plain()).is_err());
        // Stacks: same no-priority-path rules as queues.
        assert!(r.create("x", "stack", "stack+elastic:aimd:d2", plain()).is_err());
        let opts = CreateOpts { direct_quota: Some(1), ..CreateOpts::default() };
        assert!(r.create("x", "stack", "stack+elastic", opts).is_err());
    }

    #[test]
    fn counter_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("c", "counter", "elastic:fixed:2", CreateOpts::width(Some(6))).unwrap();
        assert_eq!(e.take(0, 5, false).unwrap(), 0);
        assert_eq!(e.take(1, 1, true).unwrap(), 5);
        assert_eq!(e.read(0).unwrap(), 6);
        assert!(e.enqueue(0, 1).is_err(), "counters reject queue ops");
        assert!(e.dequeue_item(0).is_err());
        let (width, previous) = e.resize(4).unwrap();
        assert_eq!((width, previous), (4, 2));
        assert_eq!(e.resize(100).unwrap().0, 6, "clamped to the max_width override");
        assert_eq!(e.set_policy(WidthPolicy::Fixed(3)).unwrap(), 3);
        let stats = e.stats_json();
        assert_eq!(stats.get("take").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-3"));
        assert_eq!(stats.get("kind").and_then(Json::as_str), Some("counter"));
    }

    #[test]
    fn direct_quota_gates_priority_takes() {
        let r = Registry::new(4);
        // Quota 0: every priority take demotes to the funnel path.
        let e = r.create("c", "counter", "elastic:fixed:2:d0", plain()).unwrap();
        assert_eq!(e.backend, "elastic:fixed:2:d0", "quota survives in the label");
        assert_eq!(e.direct_quota(), Some(0));
        assert_eq!(e.take(0, 3, true).unwrap(), 0);
        assert_eq!(e.take(1, 2, true).unwrap(), 3);
        let stats = e.stats_json();
        assert_eq!(stats.get("take_priority_demoted").and_then(Json::as_u64), Some(2));
        assert!(stats.get("take_priority").is_none(), "nothing went direct");
        assert_eq!(stats.get("direct_quota").and_then(Json::as_u64), Some(0));

        // An explicit option wins over the spec segment and shows up
        // in the canonical backend label.
        let opts = CreateOpts { direct_quota: Some(2), ..CreateOpts::default() };
        let e2 = r.create("c2", "counter", "elastic:aimd:d0", opts).unwrap();
        assert_eq!(e2.backend, "elastic:aimd:d2");
        assert_eq!(e2.direct_quota(), Some(2));
        assert_eq!(e2.take(0, 1, true).unwrap(), 0);
        let stats = e2.stats_json();
        assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
        assert!(stats.get("take_priority_demoted").is_none());

        // Unlimited (no quota) keeps the pre-quota behaviour.
        let e3 = r.create("c3", "counter", "elastic:aimd", plain()).unwrap();
        assert_eq!(e3.direct_quota(), None);
        e3.take(0, 1, true).unwrap();
        assert!(e3.stats_json().get("direct_quota").is_none());
    }

    #[test]
    fn cas_policy_threads_through_create_and_stats() {
        let r = Registry::new(2);
        // A spec `:b<policy>` suffix wins and survives in the label.
        let e = r.create("c", "counter", "elastic:fixed:2:d1:bexp", plain()).unwrap();
        assert_eq!(e.backend, "elastic:fixed:2:d1:bexp");
        assert_eq!(e.cas_policy(), Some(RetryPolicy::Exp));
        assert_eq!(e.stats_json().get("cas_policy").and_then(Json::as_str), Some("exp"));
        assert_eq!(e.take(0, 2, true).unwrap(), 0, "paced direct gate still admits");

        // Without a suffix the service default applies — silently, so
        // the label (and thus the journaled spec) stays unchanged.
        r.set_default_cas_policy(RetryPolicy::Constant);
        let d = r.create("d", "counter", "elastic:aimd", plain()).unwrap();
        assert_eq!(d.backend, "elastic:aimd");
        assert_eq!(d.cas_policy(), Some(RetryPolicy::Constant));

        // Queue index specs: suffix reaches the rings, the default
        // covers bare specs, non-paced families expose nothing.
        let q = r.create("q", "queue", "lcrq+elastic:aimd:bnone", plain()).unwrap();
        assert_eq!(q.cas_policy(), Some(RetryPolicy::None));
        let q2 = r.create("q2", "queue", "prq", plain()).unwrap();
        assert_eq!(q2.cas_policy(), Some(RetryPolicy::Constant));
        let hwq = r.create("hwq", "queue", "msq", plain()).unwrap();
        assert_eq!(hwq.cas_policy(), None);
        assert!(hwq.stats_json().get("cas_policy").is_none());

        // Live swap through the entry; the object keeps working.
        q.set_cas_policy(RetryPolicy::Adaptive);
        assert_eq!(q.cas_policy(), Some(RetryPolicy::Adaptive));
        q.enqueue(0, 1).unwrap();
        assert_eq!(q.dequeue_item(1).unwrap(), Some(Item::Int(1)));
        e.set_cas_policy(RetryPolicy::None);
        assert_eq!(e.cas_policy(), Some(RetryPolicy::None));
        assert_eq!(e.take(1, 1, false).unwrap(), 2);
        assert_eq!(e.stats_json().get("cas_policy").and_then(Json::as_str), Some("none"));
    }

    #[test]
    fn concurrent_create_delete_same_name_is_safe() {
        // The shard refactor must not regress registry races: hammer
        // one name with create/delete from several threads; every op
        // must either succeed or fail cleanly, and the final state
        // must be coherent.
        let r = Arc::new(Registry::new(4));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut created = 0u64;
                    let mut deleted = 0u64;
                    for i in 0..200 {
                        if (t + i) % 2 == 0 {
                            if r.create("contested", "counter", "elastic:aimd", plain()).is_ok()
                            {
                                created += 1;
                            }
                        } else if r.remove("contested").is_ok() {
                            deleted += 1;
                        }
                    }
                    (created, deleted)
                })
            })
            .collect();
        let (mut created, mut deleted) = (0, 0);
        for t in threads {
            let (c, d) = t.join().unwrap();
            created += c;
            deleted += d;
        }
        let live = r.get("contested").is_ok();
        assert_eq!(created, deleted + live as u64, "creates balance deletes + survivor");
        assert_eq!(r.len(), live as usize);
    }

    #[test]
    fn delete_while_enqueue_in_flight_is_safe() {
        // A data-plane op holds its own Arc: deleting the object under
        // it must not invalidate the queue mid-operation, and items
        // already enqueued through the doomed handle stay readable
        // through that handle.
        let r = Arc::new(Registry::new(4));
        r.create("doomed", "queue", "lcrq+elastic:fixed:2", plain()).unwrap();
        let entry = r.get("doomed").unwrap();
        let writer = {
            let entry = Arc::clone(&entry);
            std::thread::spawn(move || {
                let mut sent = 0u64;
                for i in 0..500u64 {
                    entry.enqueue(1, i).unwrap();
                    sent += 1;
                }
                sent
            })
        };
        // Race the delete into the middle of the enqueue storm.
        while r.remove("doomed").is_err() {
            std::hint::spin_loop();
        }
        let sent = writer.join().unwrap();
        assert_eq!(sent, 500, "enqueues on a held Arc survive the delete");
        assert!(r.get("doomed").is_err(), "name is gone from the registry");
        let mut drained = 0u64;
        while entry.dequeue_item(0).unwrap().is_some() {
            drained += 1;
        }
        assert_eq!(drained, sent, "no items lost to the race");
    }

    #[test]
    fn queue_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+elastic:fixed:2", plain()).unwrap();
        assert_eq!(e.dequeue_item(0).unwrap(), None);
        e.enqueue(0, 7).unwrap();
        e.enqueue(1, 8).unwrap();
        assert_eq!(e.dequeue_item(1).unwrap(), Some(Item::Int(7)));
        assert!(e.take(0, 1, false).is_err(), "queues reject counter ops");
        assert!(e.read(0).is_err());
        assert!(e.enqueue(0, EMPTY_ITEM).is_err(), "sentinel rejected");
        let (width, previous) = e.resize(3).unwrap();
        assert_eq!((width, previous), (3, 2));
        e.poll(); // controller tick must not panic
        let stats = e.stats_json();
        assert_eq!(stats.get("enqueue").and_then(Json::as_u64), Some(2));
        assert_eq!(stats.get("dequeue").and_then(Json::as_u64), Some(2));
        assert_eq!(stats.get("dequeue_empty").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert!(stats.get("index_cells").and_then(Json::as_u64).unwrap() >= 2);
        assert!(stats.get("main_faas").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn queue_max_width_override_applies() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+elastic:aimd", CreateOpts::width(Some(20))).unwrap();
        assert_eq!(e.resize(100).unwrap().0, 20, "clamped to the create-time override");
        let stats = e.stats_json();
        assert_eq!(stats.get("max_width").and_then(Json::as_u64), Some(20));
    }

    #[test]
    fn non_elastic_queue_has_no_width_controls() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+hw", plain()).unwrap();
        e.enqueue(0, 1).unwrap();
        assert!(e.resize(2).is_err());
        assert!(e.set_policy(WidthPolicy::SqrtP).is_err());
        e.poll(); // still a no-op, not an error
        let stats = e.stats_json();
        assert!(stats.get("active_width").is_none());
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("lcrq+hw"));
    }

    #[test]
    fn aggfunnel_counter_spec_pins_width() {
        let r = Registry::new(2);
        let e = r.create("c", "counter", "aggfunnel:3", plain()).unwrap();
        let stats = e.stats_json();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-3"));
    }

    #[test]
    fn prq_elastic_queue_has_width_controls() {
        // The elastic-PRQ satellite end to end at the registry layer:
        // a prq+elastic queue exposes the same resize/policy/stats
        // surface as lcrq+elastic and its cells ride the controller
        // walk (`poll`).
        let r = Registry::new(2);
        let e = r.create("q", "queue", "prq+elastic:fixed:2", plain()).unwrap();
        e.enqueue(0, 7).unwrap();
        assert_eq!(e.dequeue_item(1).unwrap(), Some(Item::Int(7)));
        let (width, previous) = e.resize(3).unwrap();
        assert_eq!((width, previous), (3, 2));
        assert_eq!(e.set_policy(WidthPolicy::Fixed(1)).unwrap(), 1);
        e.poll();
        let stats = e.stats_json();
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("prq+elastic:fixed:2"));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(1));
        assert!(stats.get("index_cells").and_then(Json::as_u64).unwrap() >= 2);
        assert!(stats.get("main_faas").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn prq_queue_rejects_oversized_items_cleanly() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "prq", plain()).unwrap();
        e.enqueue(0, 7).unwrap();
        assert_eq!(e.dequeue_item(1).unwrap(), Some(Item::Int(7)));
        // PRQ integer values are 48-bit on the wire: a bigger item is
        // a clean error, not a handler-killing panic. (The ring now
        // carries table indices, but the integer contract holds.)
        assert!(e.enqueue(0, 1 << 50).is_err());
        // LCRQ-family queues take anything below the sentinel.
        let wide = r.create("w", "queue", "lcrq+hw", plain()).unwrap();
        wide.enqueue(0, 1 << 50).unwrap();
        assert_eq!(wide.dequeue_item(1).unwrap(), Some(Item::Int(1 << 50)));
    }

    #[test]
    fn byte_payloads_roundtrip_through_any_queue_family() {
        let r = Registry::new(2);
        // Byte payloads ride the item table, so even the 48-bit PRQ
        // family carries them untruncated.
        for (name, spec) in [("a", "prq"), ("b", "lcrq+elastic:fixed:2"), ("c", "msq")] {
            let e = r.create(name, "queue", spec, plain()).unwrap();
            let blob = Item::Bytes(vec![0xA5; 1000]);
            e.enqueue_item(0, blob.clone()).unwrap();
            e.enqueue_item(1, Item::Int(9)).unwrap();
            assert_eq!(e.dequeue_item(1).unwrap(), Some(blob), "{spec}: FIFO order");
            assert_eq!(e.dequeue_item(0).unwrap(), Some(Item::Int(9)));
            assert_eq!(e.dequeue_item(0).unwrap(), None);
            // Oversized byte payloads are a typed error.
            let big = Item::Bytes(vec![0; MAX_ITEM_BYTES + 1]);
            let err = e.enqueue_item(0, big).unwrap_err();
            assert_eq!(super::super::error::code_of(&err), ErrorCode::ItemTooLarge);
        }
    }

    #[test]
    fn stack_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("s", "stack", "stack+elastic:fixed:2", plain()).unwrap();
        assert_eq!(e.kind(), "stack");
        assert_eq!(e.pop_item(0).unwrap(), None);
        e.push(0, 7).unwrap();
        e.push(1, 8).unwrap();
        e.push_item(0, Item::Bytes(b"top".to_vec())).unwrap();
        assert_eq!(e.pop_item(1).unwrap(), Some(Item::Bytes(b"top".to_vec())));
        assert_eq!(e.pop_item(0).unwrap(), Some(Item::Int(8)), "LIFO order");
        assert!(e.take(0, 1, false).is_err(), "stacks reject counter ops");
        assert!(e.enqueue(0, 1).is_err(), "stacks reject queue ops");
        assert!(e.dequeue_item(0).is_err());
        assert!(e.push(0, EMPTY_ITEM).is_err(), "sentinel rejected");
        let (width, previous) = e.resize(5).unwrap();
        assert_eq!((width, previous), (5, 2));
        e.poll(); // controller tick leaves stacks alone
        let stats = e.stats_json();
        assert_eq!(stats.get("kind").and_then(Json::as_str), Some("stack"));
        assert_eq!(stats.get("push").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("pop").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("pop_empty").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(5));
        assert!(stats.get("batched_ops").and_then(Json::as_u64).unwrap() >= 6);
    }

    #[test]
    fn non_elastic_stack_has_no_width_controls() {
        let r = Registry::new(2);
        let e = r.create("s", "stack", "stack+hw", plain()).unwrap();
        e.push(0, 1).unwrap();
        assert!(e.resize(2).is_err());
        assert!(e.set_policy(WidthPolicy::SqrtP).is_err());
        e.poll();
        let stats = e.stats_json();
        assert!(stats.get("active_width").is_none());
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("stack+hw"));
        // A fixed funnel width shows up but stays pinned.
        let f = r.create("f", "stack", "stack+aggfunnel:3", plain()).unwrap();
        assert!(f.resize(1).is_err());
        assert_eq!(f.stats_json().get("active_width").and_then(Json::as_u64), Some(3));
    }

    #[test]
    fn stack_cas_policy_threads_through_create_and_swap() {
        let r = Registry::new(2);
        r.set_default_cas_policy(RetryPolicy::Constant);
        let e = r.create("s", "stack", "stack+elastic:aimd:bexp", plain()).unwrap();
        assert_eq!(e.cas_policy(), Some(RetryPolicy::Exp), "spec suffix wins");
        let d = r.create("d", "stack", "stack+elastic", plain()).unwrap();
        assert_eq!(d.cas_policy(), Some(RetryPolicy::Constant), "default fills in");
        d.set_cas_policy(RetryPolicy::Adaptive);
        assert_eq!(d.cas_policy(), Some(RetryPolicy::Adaptive));
        d.push(0, 1).unwrap();
        assert_eq!(d.pop_item(1).unwrap(), Some(Item::Int(1)));
    }

    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        crate::util::scratch_dir(&format!("registry-{tag}"))
    }

    #[test]
    fn journaled_stack_recovers_lifo_order_through_the_log() {
        let dir = scratch_dir("stack-journal");
        {
            let r = Registry::new(4);
            r.set_log(Arc::new(ShardLog::open(&dir, true).unwrap()));
            let s = r.create("s", "stack", "stack+elastic:fixed:2", plain()).unwrap();
            assert!(s.persisted());
            s.push(1, 10).unwrap();
            s.push(2, 20).unwrap();
            s.push_item(1, Item::Bytes(b"blob".to_vec())).unwrap();
            s.push(2, 30).unwrap();
            assert_eq!(s.pop_item(1).unwrap(), Some(Item::Int(30)));
            // Durable integer items keep the JSON-exact bound.
            assert!(s.push(1, 1 << 60).is_err(), "item would round in the WAL");
            // Dropped without a snapshot: the WAL alone must carry it.
        }
        let log = ShardLog::open(&dir, true).unwrap();
        let objects: BTreeMap<String, super::super::persist::ObjectState> =
            log.recovered_objects().into_iter().collect();
        assert_eq!(objects["s"].kind, "stack");
        assert_eq!(objects["s"].backend, "stack+elastic:fixed:2");
        assert_eq!(
            objects["s"].items,
            std::collections::VecDeque::from(vec![
                Item::Int(10),
                Item::Int(20),
                Item::Bytes(b"blob".to_vec()),
            ]),
            "bottom-to-top, with the popped top removed"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journaled_registry_recovers_through_the_log() {
        let dir = scratch_dir("journal");
        {
            let r = Registry::new(4);
            r.set_log(Arc::new(ShardLog::open(&dir, true).unwrap()));
            let c = r.create("c", "counter", "elastic:fixed:2", plain()).unwrap();
            assert!(c.persisted());
            assert_eq!(c.take(1, 5, false).unwrap(), 0);
            assert_eq!(c.take(2, 3, true).unwrap(), 5);
            let q = r.create("q", "queue", "lcrq+elastic", plain()).unwrap();
            q.enqueue(1, 41).unwrap();
            q.enqueue(2, 42).unwrap();
            q.enqueue_item(1, Item::Bytes(b"blob".to_vec())).unwrap();
            assert_eq!(q.dequeue_item(1).unwrap(), Some(Item::Int(41)));
            // Durable items must be exactly representable in the JSON
            // WAL/snapshot model: above 2^53 is a clean error here
            // (a non-persisted lcrq queue would accept it).
            assert!(q.enqueue(1, 1 << 60).is_err(), "item would round in the WAL");
            r.create("gone", "counter", "elastic:aimd", plain()).unwrap();
            r.remove("gone").unwrap();
            // Dropped without a snapshot: the WAL alone must carry it.
        }
        let log = ShardLog::open(&dir, true).unwrap();
        let objects: BTreeMap<String, super::super::persist::ObjectState> =
            log.recovered_objects().into_iter().collect();
        assert_eq!(objects.len(), 2, "deleted object must not be recovered");
        assert_eq!(objects["c"].counter, 8, "max of the acked post-take values");
        assert_eq!(objects["c"].backend, "elastic:fixed:2");
        assert_eq!(
            objects["q"].items,
            std::collections::VecDeque::from(vec![
                Item::Int(42),
                Item::Bytes(b"blob".to_vec()),
            ])
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn persist_opt_out_keeps_object_ephemeral() {
        let dir = scratch_dir("optout");
        {
            let r = Registry::new(2);
            r.set_log(Arc::new(ShardLog::open(&dir, true).unwrap()));
            let opts = CreateOpts { persist: false, ..CreateOpts::default() };
            let e = r.create("scratch", "counter", "elastic:aimd", opts).unwrap();
            assert!(!e.persisted());
            e.take(1, 9, false).unwrap();
            assert_eq!(
                e.stats_json().get("persist").and_then(Json::as_bool),
                Some(false)
            );
            r.create("kept", "counter", "elastic:aimd", plain()).unwrap();
        }
        let log = ShardLog::open(&dir, true).unwrap();
        let names: Vec<String> =
            log.recovered_objects().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["kept"], "opted-out object left no trace");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn late_ops_on_deleted_handles_do_not_leak_into_recreated_objects() {
        let dir = scratch_dir("reuse");
        {
            let r = Registry::new(2);
            r.set_log(Arc::new(ShardLog::open(&dir, true).unwrap()));
            let old = r.create("c", "counter", "elastic:fixed:1", plain()).unwrap();
            old.take(1, 100, false).unwrap();
            r.remove("c").unwrap();
            let fresh = r.create("c", "counter", "elastic:fixed:1", plain()).unwrap();
            fresh.take(1, 3, false).unwrap();
            // A straggler still holding the deleted entry's Arc: its
            // in-memory op works, but nothing is journaled under the
            // re-created name.
            old.take(1, 500, false).unwrap();
            assert_eq!(fresh.read(1).unwrap(), 3);
        }
        let log = ShardLog::open(&dir, true).unwrap();
        let objects = log.recovered_objects();
        assert_eq!(objects.len(), 1);
        assert_eq!(objects[0].1.counter, 3, "straggler value leaked into the new object");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
