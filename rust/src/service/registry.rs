//! The object registry: named fetch-and-add counters and funnel-backed
//! FIFO queues living behind one wire protocol.
//!
//! A registry maps names to [`ObjectEntry`]s. An entry is either a
//! **counter** — an [`ElasticAggFunnel`] with a per-object
//! [`WidthPolicy`], today's ticket counter made nameable — or a
//! **queue** — any [`crate::queue::make_queue`] spec, with
//! `lcrq+elastic` queues keeping an [`ElasticIndexFactory`] handle so
//! the service's resize controller can walk a queue's ring indices
//! exactly like a counter's Aggregator set. Every entry carries its
//! own [`Metrics`] so `stats` reports independent per-object traffic
//! and contention counters.
//!
//! Lookups take a read lock and clone an `Arc` out; the data-plane ops
//! (`take`, `enqueue`, …) then run lock-free on the object itself.
//! `create`/`delete` are control-plane and take the write lock.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

use super::metrics::Metrics;
use crate::config::ObjectManifest;
use crate::faa::{backend, BackendSpec, BatchStats, ElasticAggFunnel, FetchAddObject, WidthPolicy};
use crate::queue::{make_queue_with_handle, ConcurrentQueue, ElasticIndexFactory, EMPTY_ITEM};
use crate::util::json::Json;

/// The object un-named requests route to (the pre-registry protocol's
/// single anonymous ticket counter, now just a well-known name).
pub const DEFAULT_OBJECT: &str = "tickets";

/// A served object's body.
pub enum ObjectBody {
    Counter(ElasticAggFunnel),
    Queue {
        queue: Arc<dyn ConcurrentQueue>,
        /// Present iff the index backend is elastic (resizable).
        elastic: Option<ElasticIndexFactory>,
    },
}

/// One named object: body + backend label + per-object metrics +
/// runtime-swappable width policy.
pub struct ObjectEntry {
    pub name: String,
    /// Canonical backend spec (re-parseable; shown by `list`).
    pub backend: String,
    pub metrics: Metrics,
    policy: Mutex<WidthPolicy>,
    body: ObjectBody,
}

impl ObjectEntry {
    pub fn kind(&self) -> &'static str {
        match self.body {
            ObjectBody::Counter(_) => "counter",
            ObjectBody::Queue { .. } => "queue",
        }
    }

    fn as_counter(&self, op: &str) -> Result<&ElasticAggFunnel> {
        match &self.body {
            ObjectBody::Counter(f) => Ok(f),
            ObjectBody::Queue { .. } => {
                Err(anyhow!("object {:?} is a queue; {op} needs a counter", self.name))
            }
        }
    }

    fn as_queue(&self, op: &str) -> Result<&Arc<dyn ConcurrentQueue>> {
        match &self.body {
            ObjectBody::Queue { queue, .. } => Ok(queue),
            ObjectBody::Counter(_) => {
                Err(anyhow!("object {:?} is a counter; {op} needs a queue", self.name))
            }
        }
    }

    /// Counter op: `Fetch&Add(count)`, direct when `priority`.
    pub fn take(&self, tid: usize, count: u64, priority: bool) -> Result<u64> {
        let funnel = self.as_counter("take")?;
        Ok(if priority {
            self.metrics.incr("take_priority");
            funnel.fetch_add_direct(tid, count as i64)
        } else {
            self.metrics.incr("take");
            funnel.fetch_add(tid, count as i64)
        })
    }

    /// Counter op: linearizable read.
    pub fn read(&self, tid: usize) -> Result<u64> {
        let funnel = self.as_counter("read")?;
        self.metrics.incr("read");
        Ok(funnel.read(tid))
    }

    /// Queue op: enqueue one item.
    pub fn enqueue(&self, tid: usize, item: u64) -> Result<()> {
        if item >= EMPTY_ITEM {
            return Err(anyhow!("item {item} is reserved"));
        }
        let queue = self.as_queue("enqueue")?;
        self.metrics.incr("enqueue");
        queue.enqueue(tid, item);
        Ok(())
    }

    /// Queue op: dequeue the oldest item (`None` on empty).
    pub fn dequeue(&self, tid: usize) -> Result<Option<u64>> {
        let queue = self.as_queue("dequeue")?;
        self.metrics.incr("dequeue");
        let got = queue.dequeue(tid);
        if got.is_none() {
            self.metrics.incr("dequeue_empty");
        }
        Ok(got)
    }

    /// Set the active funnel width: the Aggregator prefix for a
    /// counter, every live ring index for an elastic-index queue.
    /// Returns `(new_width, previous_width)`.
    pub fn resize(&self, width: usize) -> Result<(usize, usize)> {
        self.metrics.incr("resize");
        match &self.body {
            ObjectBody::Counter(f) => {
                let previous = f.resize(width);
                Ok((f.active_width(), previous))
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                let previous = factory.active_width();
                Ok((factory.resize(width), previous))
            }
            ObjectBody::Queue { .. } => {
                Err(anyhow!("queue {:?} has a non-resizable {:?} index", self.name, self.backend))
            }
        }
    }

    /// Swap the width policy at runtime; applies once immediately.
    /// Returns the active width now in force.
    pub fn set_policy(&self, policy: WidthPolicy) -> Result<usize> {
        self.metrics.incr("policy");
        match &self.body {
            ObjectBody::Counter(f) => {
                *self.policy.lock().unwrap() = policy;
                Ok(f.poll_policy(&policy))
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                *self.policy.lock().unwrap() = policy;
                // Through the factory so future rings' cells are built
                // under the new policy too.
                Ok(factory.set_policy(policy))
            }
            ObjectBody::Queue { .. } => {
                Err(anyhow!("queue {:?} has a non-resizable {:?} index", self.name, self.backend))
            }
        }
    }

    /// The current width policy.
    pub fn policy(&self) -> WidthPolicy {
        *self.policy.lock().unwrap()
    }

    /// One resize-controller tick: apply the object's policy to its
    /// contention window. No-op for non-elastic queues.
    pub fn poll(&self) {
        let policy = self.policy();
        match &self.body {
            ObjectBody::Counter(f) => {
                f.poll_policy(&policy);
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                factory.poll_policy(&policy);
            }
            ObjectBody::Queue { .. } => {}
        }
    }

    /// The object's combining statistics (counter funnel, or queue
    /// ring indices for batching index backends).
    pub fn batch_stats(&self) -> BatchStats {
        match &self.body {
            ObjectBody::Counter(f) => f.batch_stats(),
            ObjectBody::Queue { queue, .. } => queue.batch_stats(),
        }
    }

    /// Per-object `stats` payload: identity, per-object traffic
    /// counters, and independent width/contention counters.
    pub fn stats_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert("name".to_string(), Json::str(self.name.clone()));
        obj.insert("kind".to_string(), Json::str(self.kind()));
        obj.insert("backend".to_string(), Json::str(self.backend.clone()));
        for (k, v) in self.metrics.snapshot() {
            obj.insert(k, Json::num(v as f64));
        }
        let stats = self.batch_stats();
        for (k, v) in [
            ("main_faas", stats.main_faas),
            ("batched_ops", stats.ops),
            ("single_op_batches", stats.single_op_batches),
            ("cas_failures", stats.cas_failures),
        ] {
            obj.insert(k.to_string(), Json::num(v as f64));
        }
        obj.insert("avg_batch".to_string(), Json::num(stats.avg_batch_size()));
        match &self.body {
            ObjectBody::Counter(f) => {
                obj.insert("active_width".to_string(), Json::num(f.active_width() as f64));
                obj.insert("max_width".to_string(), Json::num(f.max_width() as f64));
                obj.insert("resizes".to_string(), Json::num(f.resizes() as f64));
                obj.insert("width_policy".to_string(), Json::str(self.policy().label()));
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                obj.insert("active_width".to_string(), Json::num(factory.active_width() as f64));
                obj.insert("max_width".to_string(), Json::num(factory.max_width() as f64));
                obj.insert("index_cells".to_string(), Json::num(factory.live_cells() as f64));
                obj.insert("width_policy".to_string(), Json::str(self.policy().label()));
            }
            ObjectBody::Queue { .. } => {}
        }
        Json::Obj(obj)
    }
}

/// The concurrent name → object map.
pub struct Registry {
    map: RwLock<BTreeMap<String, Arc<ObjectEntry>>>,
    /// Funnel tid bound every created object is built for (the
    /// service's lease-pool size plus the reserved tid 0).
    max_threads: usize,
}

impl Registry {
    pub fn new(max_threads: usize) -> Self {
        Self { map: RwLock::new(BTreeMap::new()), max_threads: max_threads.max(1) }
    }

    /// Create a counter directly from a policy (the boot path for the
    /// default object, where the policy is already parsed). `initial`
    /// overrides the policy's starting width.
    pub fn create_counter(
        &self,
        name: &str,
        policy: WidthPolicy,
        max_width: usize,
        initial: Option<usize>,
    ) -> Result<Arc<ObjectEntry>> {
        let spec = BackendSpec::Elastic { policy, max_width: max_width.max(1) };
        let funnel = backend::build_elastic(self.max_threads, policy, max_width.max(1));
        if let Some(w) = initial {
            funnel.resize(w);
        }
        self.insert(ObjectEntry {
            name: validated_name(name)?,
            backend: spec.label(),
            metrics: Metrics::new(),
            policy: Mutex::new(policy),
            body: ObjectBody::Counter(funnel),
        })
    }

    /// Create an object from wire/manifest strings. An empty
    /// `backend_spec` takes the kind's default; `max_width` overrides
    /// the elastic slot capacity when given.
    pub fn create(
        &self,
        name: &str,
        kind: &str,
        backend_spec: &str,
        max_width: Option<usize>,
    ) -> Result<Arc<ObjectEntry>> {
        let backend_spec = if backend_spec.is_empty() {
            ObjectManifest::default_backend(kind).unwrap_or("")
        } else {
            backend_spec
        };
        match kind {
            "counter" => {
                let mut spec = BackendSpec::parse(backend_spec)
                    .ok_or_else(|| anyhow!("unknown counter backend {backend_spec:?}"))?;
                if let Some(w) = max_width {
                    spec = spec.with_max_width(w);
                }
                let (policy, width) = spec.counter_policy().ok_or_else(|| {
                    anyhow!(
                        "counter backend {backend_spec:?} does not batch; \
                         use aggfunnel:<m> or elastic:<policy>"
                    )
                })?;
                self.create_counter(name, policy, width, None)
            }
            "queue" => {
                let (queue, elastic) =
                    make_queue_with_handle(backend_spec, self.max_threads, max_width)
                        .ok_or_else(|| anyhow!("unknown queue backend {backend_spec:?}"))?;
                let policy = match backend_spec.split_once('+') {
                    Some((_, index)) => match BackendSpec::parse(index) {
                        Some(BackendSpec::Elastic { policy, .. }) => policy,
                        _ => WidthPolicy::Fixed(backend::DEFAULT_AGGREGATORS),
                    },
                    None => WidthPolicy::Fixed(backend::DEFAULT_AGGREGATORS),
                };
                self.insert(ObjectEntry {
                    name: validated_name(name)?,
                    backend: backend_spec.trim().to_string(),
                    metrics: Metrics::new(),
                    policy: Mutex::new(policy),
                    body: ObjectBody::Queue { queue, elastic },
                })
            }
            other => Err(anyhow!("unknown object kind {other:?} (counter | queue)")),
        }
    }

    fn insert(&self, entry: ObjectEntry) -> Result<Arc<ObjectEntry>> {
        let mut map = self.map.write().unwrap();
        if map.contains_key(&entry.name) {
            return Err(anyhow!("object {:?} already exists", entry.name));
        }
        let entry = Arc::new(entry);
        map.insert(entry.name.clone(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Look an object up by name.
    pub fn get(&self, name: &str) -> Result<Arc<ObjectEntry>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no object named {name:?}"))
    }

    /// Delete an object. In-flight data-plane ops on other
    /// connections hold their own `Arc` and finish normally.
    pub fn remove(&self, name: &str) -> Result<()> {
        self.map
            .write()
            .unwrap()
            .remove(name)
            .map(drop)
            .ok_or_else(|| anyhow!("no object named {name:?}"))
    }

    /// Every registered object, in name order.
    pub fn list(&self) -> Vec<Arc<ObjectEntry>> {
        self.map.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.read().unwrap().is_empty()
    }
}

/// Object names share the config-key charset, so every valid name is
/// also addressable from an `[objects.<name>]` manifest section.
fn validated_name(name: &str) -> Result<String> {
    if name.is_empty() || name.len() > 64 {
        return Err(anyhow!("object names must be 1..=64 characters"));
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
        return Err(anyhow!("invalid object name {name:?} (use [A-Za-z0-9_-])"));
    }
    Ok(name.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_backend_defaults_per_kind() {
        let r = Registry::new(2);
        let c = r.create("c", "counter", "", None).unwrap();
        assert_eq!(c.backend, "elastic:aimd");
        let q = r.create("q", "queue", "", None).unwrap();
        assert_eq!(q.backend, "lcrq+elastic");
        q.enqueue(0, 1).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(1));
        assert!(r.create("x", "stack", "", None).is_err(), "kind still validated");
    }

    #[test]
    fn create_get_list_delete() {
        let r = Registry::new(4);
        r.create("c1", "counter", "elastic:aimd", None).unwrap();
        r.create("q1", "queue", "lcrq+elastic", None).unwrap();
        assert_eq!(r.len(), 2);
        assert!(!r.is_empty());
        assert!(r.create("c1", "counter", "elastic:aimd", None).is_err(), "duplicate");
        let names: Vec<String> = r.list().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["c1", "q1"], "name order");
        assert_eq!(r.get("c1").unwrap().kind(), "counter");
        assert_eq!(r.get("q1").unwrap().kind(), "queue");
        r.remove("c1").unwrap();
        assert!(r.get("c1").is_err());
        assert!(r.remove("c1").is_err());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn invalid_specs_rejected() {
        let r = Registry::new(2);
        assert!(r.create("x", "counter", "bogus", None).is_err());
        assert!(r.create("x", "counter", "hw", None).is_err(), "hw counters have no width");
        assert!(r.create("x", "queue", "bogus", None).is_err());
        assert!(r.create("x", "stack", "lcrq", None).is_err());
        assert!(r.create("", "counter", "elastic", None).is_err());
        assert!(r.create("a b", "counter", "elastic", None).is_err());
        assert!(r.create(&"n".repeat(65), "counter", "elastic", None).is_err());
    }

    #[test]
    fn counter_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("c", "counter", "elastic:fixed:2", Some(6)).unwrap();
        assert_eq!(e.take(0, 5, false).unwrap(), 0);
        assert_eq!(e.take(1, 1, true).unwrap(), 5);
        assert_eq!(e.read(0).unwrap(), 6);
        assert!(e.enqueue(0, 1).is_err(), "counters reject queue ops");
        assert!(e.dequeue(0).is_err());
        let (width, previous) = e.resize(4).unwrap();
        assert_eq!((width, previous), (4, 2));
        assert_eq!(e.resize(100).unwrap().0, 6, "clamped to the max_width override");
        assert_eq!(e.set_policy(WidthPolicy::Fixed(3)).unwrap(), 3);
        let stats = e.stats_json();
        assert_eq!(stats.get("take").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-3"));
        assert_eq!(stats.get("kind").and_then(Json::as_str), Some("counter"));
    }

    #[test]
    fn queue_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+elastic:fixed:2", None).unwrap();
        assert_eq!(e.dequeue(0).unwrap(), None);
        e.enqueue(0, 7).unwrap();
        e.enqueue(1, 8).unwrap();
        assert_eq!(e.dequeue(1).unwrap(), Some(7));
        assert!(e.take(0, 1, false).is_err(), "queues reject counter ops");
        assert!(e.read(0).is_err());
        assert!(e.enqueue(0, EMPTY_ITEM).is_err(), "sentinel rejected");
        let (width, previous) = e.resize(3).unwrap();
        assert_eq!((width, previous), (3, 2));
        e.poll(); // controller tick must not panic
        let stats = e.stats_json();
        assert_eq!(stats.get("enqueue").and_then(Json::as_u64), Some(2));
        assert_eq!(stats.get("dequeue").and_then(Json::as_u64), Some(2));
        assert_eq!(stats.get("dequeue_empty").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert!(stats.get("index_cells").and_then(Json::as_u64).unwrap() >= 2);
        assert!(stats.get("main_faas").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn queue_max_width_override_applies() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+elastic:aimd", Some(20)).unwrap();
        assert_eq!(e.resize(100).unwrap().0, 20, "clamped to the create-time override");
        let stats = e.stats_json();
        assert_eq!(stats.get("max_width").and_then(Json::as_u64), Some(20));
    }

    #[test]
    fn non_elastic_queue_has_no_width_controls() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+hw", None).unwrap();
        e.enqueue(0, 1).unwrap();
        assert!(e.resize(2).is_err());
        assert!(e.set_policy(WidthPolicy::SqrtP).is_err());
        e.poll(); // still a no-op, not an error
        let stats = e.stats_json();
        assert!(stats.get("active_width").is_none());
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("lcrq+hw"));
    }

    #[test]
    fn aggfunnel_counter_spec_pins_width() {
        let r = Registry::new(2);
        let e = r.create("c", "counter", "aggfunnel:3", None).unwrap();
        let stats = e.stats_json();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-3"));
    }
}
