//! The object registry: named fetch-and-add counters and funnel-backed
//! FIFO queues living behind one wire protocol.
//!
//! A registry maps names to [`ObjectEntry`]s. An entry is either a
//! **counter** — an [`ElasticAggFunnel`] with a per-object
//! [`WidthPolicy`], today's ticket counter made nameable — or a
//! **queue** — any [`crate::queue::make_queue`] spec, with
//! `lcrq+elastic` queues keeping an [`ElasticIndexFactory`] handle so
//! the service's resize controller can walk a queue's ring indices
//! exactly like a counter's Aggregator set. Every entry carries its
//! own [`Metrics`] so `stats` reports independent per-object traffic
//! and contention counters.
//!
//! Lookups take a read lock and clone an `Arc` out; the data-plane ops
//! (`take`, `enqueue`, …) then run lock-free on the object itself.
//! `create`/`delete` are control-plane and take the write lock.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

use super::metrics::Metrics;
use crate::config::ObjectManifest;
use crate::faa::backend::DirectPermits;
use crate::faa::{backend, BackendSpec, BatchStats, ElasticAggFunnel, FetchAddObject, WidthPolicy};
use crate::queue::{make_queue_with_handle, ConcurrentQueue, ElasticIndexFactory, EMPTY_ITEM};
use crate::util::json::Json;

/// The object un-named requests route to (the pre-registry protocol's
/// single anonymous ticket counter, now just a well-known name).
pub const DEFAULT_OBJECT: &str = "tickets";

/// Per-object creation options beyond the backend spec string.
#[derive(Clone, Copy, Debug, Default)]
pub struct CreateOpts {
    /// Elastic slot capacity override.
    pub max_width: Option<usize>,
    /// §4.4 direct-thread quota `d`: at most this many `priority`
    /// requests ride `Fetch&AddDirect` concurrently; the rest are
    /// demoted to the funnel path. `None` = unlimited (every priority
    /// request goes direct). Counters only. Overrides a `:d<k>`
    /// segment in the backend spec.
    pub direct_quota: Option<usize>,
}

impl CreateOpts {
    /// Only a width override (the historical `create` option set).
    pub fn width(max_width: Option<usize>) -> Self {
        Self { max_width, direct_quota: None }
    }
}

/// A served object's body.
pub enum ObjectBody {
    Counter(ElasticAggFunnel),
    Queue {
        queue: Arc<dyn ConcurrentQueue>,
        /// Present iff the index backend is elastic (resizable).
        elastic: Option<ElasticIndexFactory>,
    },
}

/// One named object: body + backend label + per-object metrics +
/// runtime-swappable width policy.
pub struct ObjectEntry {
    pub name: String,
    /// Canonical backend spec (re-parseable; shown by `list`).
    pub backend: String,
    pub metrics: Metrics,
    policy: Mutex<WidthPolicy>,
    /// §4.4 direct-thread quota gate; `None` = unlimited direct. The
    /// entry gates here (rather than wrapping the funnel in a
    /// [`backend::DirectQuota`]) so demotions are visible in the
    /// per-object metrics.
    direct: Option<DirectPermits>,
    body: ObjectBody,
}

impl ObjectEntry {
    pub fn kind(&self) -> &'static str {
        match self.body {
            ObjectBody::Counter(_) => "counter",
            ObjectBody::Queue { .. } => "queue",
        }
    }

    fn as_counter(&self, op: &str) -> Result<&ElasticAggFunnel> {
        match &self.body {
            ObjectBody::Counter(f) => Ok(f),
            ObjectBody::Queue { .. } => {
                Err(anyhow!("object {:?} is a queue; {op} needs a counter", self.name))
            }
        }
    }

    fn as_queue(&self, op: &str) -> Result<&Arc<dyn ConcurrentQueue>> {
        match &self.body {
            ObjectBody::Queue { queue, .. } => Ok(queue),
            ObjectBody::Counter(_) => {
                Err(anyhow!("object {:?} is a counter; {op} needs a queue", self.name))
            }
        }
    }

    /// Counter op: `Fetch&Add(count)`; `priority` requests take the
    /// §4.4 `Fetch&AddDirect` fast path while the object's
    /// direct-thread quota has a free slot, and are demoted to the
    /// funnel (counted as `take_priority_demoted`) when it does not.
    pub fn take(&self, tid: usize, count: u64, priority: bool) -> Result<u64> {
        let funnel = self.as_counter("take")?;
        if priority {
            match &self.direct {
                None => {
                    self.metrics.incr("take_priority");
                    return Ok(funnel.fetch_add_direct(tid, count as i64));
                }
                Some(gate) if gate.try_acquire() => {
                    self.metrics.incr("take_priority");
                    let v = funnel.fetch_add_direct(tid, count as i64);
                    gate.release();
                    return Ok(v);
                }
                Some(_) => {
                    // Quota exhausted: priority demotes to the shared
                    // funnel path instead of overloading `Main`.
                    self.metrics.incr("take_priority_demoted");
                    return Ok(funnel.fetch_add(tid, count as i64));
                }
            }
        }
        self.metrics.incr("take");
        Ok(funnel.fetch_add(tid, count as i64))
    }

    /// The configured §4.4 direct quota (`None` = unlimited).
    pub fn direct_quota(&self) -> Option<usize> {
        self.direct.as_ref().map(DirectPermits::quota)
    }

    /// Counter op: linearizable read.
    pub fn read(&self, tid: usize) -> Result<u64> {
        let funnel = self.as_counter("read")?;
        self.metrics.incr("read");
        Ok(funnel.read(tid))
    }

    /// Queue op: enqueue one item.
    pub fn enqueue(&self, tid: usize, item: u64) -> Result<()> {
        if item >= EMPTY_ITEM {
            return Err(anyhow!("item {item} is reserved"));
        }
        let queue = self.as_queue("enqueue")?;
        self.metrics.incr("enqueue");
        queue.enqueue(tid, item);
        Ok(())
    }

    /// Queue op: dequeue the oldest item (`None` on empty).
    pub fn dequeue(&self, tid: usize) -> Result<Option<u64>> {
        let queue = self.as_queue("dequeue")?;
        self.metrics.incr("dequeue");
        let got = queue.dequeue(tid);
        if got.is_none() {
            self.metrics.incr("dequeue_empty");
        }
        Ok(got)
    }

    /// Set the active funnel width: the Aggregator prefix for a
    /// counter, every live ring index for an elastic-index queue.
    /// Returns `(new_width, previous_width)`.
    pub fn resize(&self, width: usize) -> Result<(usize, usize)> {
        self.metrics.incr("resize");
        match &self.body {
            ObjectBody::Counter(f) => {
                let previous = f.resize(width);
                Ok((f.active_width(), previous))
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                let previous = factory.active_width();
                Ok((factory.resize(width), previous))
            }
            ObjectBody::Queue { .. } => {
                Err(anyhow!("queue {:?} has a non-resizable {:?} index", self.name, self.backend))
            }
        }
    }

    /// Swap the width policy at runtime; applies once immediately.
    /// Returns the active width now in force.
    pub fn set_policy(&self, policy: WidthPolicy) -> Result<usize> {
        self.metrics.incr("policy");
        match &self.body {
            ObjectBody::Counter(f) => {
                *self.policy.lock().unwrap() = policy;
                Ok(f.poll_policy(&policy))
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                *self.policy.lock().unwrap() = policy;
                // Through the factory so future rings' cells are built
                // under the new policy too.
                Ok(factory.set_policy(policy))
            }
            ObjectBody::Queue { .. } => {
                Err(anyhow!("queue {:?} has a non-resizable {:?} index", self.name, self.backend))
            }
        }
    }

    /// The current width policy.
    pub fn policy(&self) -> WidthPolicy {
        *self.policy.lock().unwrap()
    }

    /// One resize-controller tick: apply the object's policy to its
    /// contention window. No-op for non-elastic queues.
    pub fn poll(&self) {
        let policy = self.policy();
        match &self.body {
            ObjectBody::Counter(f) => {
                f.poll_policy(&policy);
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                factory.poll_policy(&policy);
            }
            ObjectBody::Queue { .. } => {}
        }
    }

    /// The object's combining statistics (counter funnel, or queue
    /// ring indices for batching index backends).
    pub fn batch_stats(&self) -> BatchStats {
        match &self.body {
            ObjectBody::Counter(f) => f.batch_stats(),
            ObjectBody::Queue { queue, .. } => queue.batch_stats(),
        }
    }

    /// Per-object `stats` payload: identity, per-object traffic
    /// counters, and independent width/contention counters.
    pub fn stats_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert("name".to_string(), Json::str(self.name.clone()));
        obj.insert("kind".to_string(), Json::str(self.kind()));
        obj.insert("backend".to_string(), Json::str(self.backend.clone()));
        for (k, v) in self.metrics.snapshot() {
            obj.insert(k, Json::num(v as f64));
        }
        let stats = self.batch_stats();
        for (k, v) in [
            ("main_faas", stats.main_faas),
            ("batched_ops", stats.ops),
            ("single_op_batches", stats.single_op_batches),
            ("cas_failures", stats.cas_failures),
        ] {
            obj.insert(k.to_string(), Json::num(v as f64));
        }
        obj.insert("avg_batch".to_string(), Json::num(stats.avg_batch_size()));
        match &self.body {
            ObjectBody::Counter(f) => {
                obj.insert("active_width".to_string(), Json::num(f.active_width() as f64));
                obj.insert("max_width".to_string(), Json::num(f.max_width() as f64));
                obj.insert("resizes".to_string(), Json::num(f.resizes() as f64));
                obj.insert("width_policy".to_string(), Json::str(self.policy().label()));
                if let Some(d) = self.direct_quota() {
                    obj.insert("direct_quota".to_string(), Json::num(d as f64));
                }
            }
            ObjectBody::Queue { elastic: Some(factory), .. } => {
                obj.insert("active_width".to_string(), Json::num(factory.active_width() as f64));
                obj.insert("max_width".to_string(), Json::num(factory.max_width() as f64));
                obj.insert("index_cells".to_string(), Json::num(factory.live_cells() as f64));
                obj.insert("width_policy".to_string(), Json::str(self.policy().label()));
            }
            ObjectBody::Queue { .. } => {}
        }
        Json::Obj(obj)
    }
}

/// The concurrent name → object map.
pub struct Registry {
    map: RwLock<BTreeMap<String, Arc<ObjectEntry>>>,
    /// Funnel tid bound every created object is built for (the
    /// service's lease-pool size plus the reserved tid 0).
    max_threads: usize,
}

impl Registry {
    pub fn new(max_threads: usize) -> Self {
        Self { map: RwLock::new(BTreeMap::new()), max_threads: max_threads.max(1) }
    }

    /// Create a counter directly from a policy (the boot path for the
    /// default object, where the policy is already parsed). `initial`
    /// overrides the policy's starting width; `direct_quota` is the
    /// §4.4 `d` parameter (`None` = unlimited direct).
    pub fn create_counter(
        &self,
        name: &str,
        policy: WidthPolicy,
        max_width: usize,
        initial: Option<usize>,
        direct_quota: Option<usize>,
    ) -> Result<Arc<ObjectEntry>> {
        let mut spec = BackendSpec::Elastic {
            policy,
            max_width: max_width.max(1),
            direct: None,
        };
        if let Some(d) = direct_quota {
            spec = spec.with_direct_quota(d);
        }
        let funnel = backend::build_elastic(self.max_threads, policy, max_width.max(1));
        if let Some(w) = initial {
            funnel.resize(w);
        }
        self.insert(ObjectEntry {
            name: validated_name(name)?,
            backend: spec.label(),
            metrics: Metrics::new(),
            policy: Mutex::new(policy),
            direct: direct_quota.map(DirectPermits::new),
            body: ObjectBody::Counter(funnel),
        })
    }

    /// Create an object from wire/manifest strings. An empty
    /// `backend_spec` takes the kind's default; [`CreateOpts`] carries
    /// the per-object overrides (elastic slot capacity, §4.4 direct
    /// quota).
    pub fn create(
        &self,
        name: &str,
        kind: &str,
        backend_spec: &str,
        opts: CreateOpts,
    ) -> Result<Arc<ObjectEntry>> {
        let backend_spec = if backend_spec.is_empty() {
            ObjectManifest::default_backend(kind).unwrap_or("")
        } else {
            backend_spec
        };
        match kind {
            "counter" => {
                let mut spec = BackendSpec::parse(backend_spec)
                    .ok_or_else(|| anyhow!("unknown counter backend {backend_spec:?}"))?;
                if let Some(w) = opts.max_width {
                    spec = spec.with_max_width(w);
                }
                // An explicit option wins over a `:d<k>` spec segment.
                if let Some(d) = opts.direct_quota {
                    spec = spec.with_direct_quota(d);
                }
                let (policy, width) = spec.counter_policy().ok_or_else(|| {
                    anyhow!(
                        "counter backend {backend_spec:?} does not batch; \
                         use aggfunnel:<m> or elastic:<policy>"
                    )
                })?;
                self.create_counter(name, policy, width, None, spec.direct_quota())
            }
            "queue" => {
                if opts.direct_quota.is_some() {
                    return Err(anyhow!(
                        "direct_quota applies to counters; queue {name:?} has no priority path"
                    ));
                }
                // A `:d<k>` segment on the index spec would be
                // silently inert (ring indices have no priority
                // path), so reject it like the explicit option
                // instead of echoing a quota that isn't enforced.
                let index_spec = backend_spec.split_once('+').map(|(_, index)| index);
                if index_spec
                    .and_then(BackendSpec::parse)
                    .and_then(|s| s.direct_quota())
                    .is_some()
                {
                    return Err(anyhow!(
                        "direct quota applies to counters; queue index spec {backend_spec:?} \
                         cannot carry :d<k>"
                    ));
                }
                let (queue, elastic) =
                    make_queue_with_handle(backend_spec, self.max_threads, opts.max_width)
                        .ok_or_else(|| anyhow!("unknown queue backend {backend_spec:?}"))?;
                let policy = match index_spec.and_then(BackendSpec::parse) {
                    Some(BackendSpec::Elastic { policy, .. }) => policy,
                    _ => WidthPolicy::Fixed(backend::DEFAULT_AGGREGATORS),
                };
                self.insert(ObjectEntry {
                    name: validated_name(name)?,
                    backend: backend_spec.trim().to_string(),
                    metrics: Metrics::new(),
                    policy: Mutex::new(policy),
                    direct: None,
                    body: ObjectBody::Queue { queue, elastic },
                })
            }
            other => Err(anyhow!("unknown object kind {other:?} (counter | queue)")),
        }
    }

    fn insert(&self, entry: ObjectEntry) -> Result<Arc<ObjectEntry>> {
        let mut map = self.map.write().unwrap();
        if map.contains_key(&entry.name) {
            return Err(anyhow!("object {:?} already exists", entry.name));
        }
        let entry = Arc::new(entry);
        map.insert(entry.name.clone(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Look an object up by name.
    pub fn get(&self, name: &str) -> Result<Arc<ObjectEntry>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no object named {name:?}"))
    }

    /// Delete an object. In-flight data-plane ops on other
    /// connections hold their own `Arc` and finish normally.
    pub fn remove(&self, name: &str) -> Result<()> {
        self.map
            .write()
            .unwrap()
            .remove(name)
            .map(drop)
            .ok_or_else(|| anyhow!("no object named {name:?}"))
    }

    /// Every registered object, in name order.
    pub fn list(&self) -> Vec<Arc<ObjectEntry>> {
        self.map.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.read().unwrap().is_empty()
    }
}

/// Object names share the config-key charset, so every valid name is
/// also addressable from an `[objects.<name>]` manifest section.
fn validated_name(name: &str) -> Result<String> {
    if name.is_empty() || name.len() > 64 {
        return Err(anyhow!("object names must be 1..=64 characters"));
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
        return Err(anyhow!("invalid object name {name:?} (use [A-Za-z0-9_-])"));
    }
    Ok(name.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plain() -> CreateOpts {
        CreateOpts::default()
    }

    #[test]
    fn empty_backend_defaults_per_kind() {
        let r = Registry::new(2);
        let c = r.create("c", "counter", "", plain()).unwrap();
        assert_eq!(c.backend, "elastic:aimd");
        let q = r.create("q", "queue", "", plain()).unwrap();
        assert_eq!(q.backend, "lcrq+elastic");
        q.enqueue(0, 1).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(1));
        assert!(r.create("x", "stack", "", plain()).is_err(), "kind still validated");
    }

    #[test]
    fn create_get_list_delete() {
        let r = Registry::new(4);
        r.create("c1", "counter", "elastic:aimd", plain()).unwrap();
        r.create("q1", "queue", "lcrq+elastic", plain()).unwrap();
        assert_eq!(r.len(), 2);
        assert!(!r.is_empty());
        assert!(r.create("c1", "counter", "elastic:aimd", plain()).is_err(), "duplicate");
        let names: Vec<String> = r.list().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["c1", "q1"], "name order");
        assert_eq!(r.get("c1").unwrap().kind(), "counter");
        assert_eq!(r.get("q1").unwrap().kind(), "queue");
        r.remove("c1").unwrap();
        assert!(r.get("c1").is_err());
        assert!(r.remove("c1").is_err());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn list_is_sorted_regardless_of_creation_order() {
        let r = Registry::new(2);
        for name in ["zeta", "alpha", "mid", "beta"] {
            r.create(name, "counter", "elastic:aimd", plain()).unwrap();
        }
        let names: Vec<String> = r.list().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["alpha", "beta", "mid", "zeta"]);
    }

    #[test]
    fn invalid_specs_rejected() {
        let r = Registry::new(2);
        assert!(r.create("x", "counter", "bogus", plain()).is_err());
        assert!(r.create("x", "counter", "hw", plain()).is_err(), "hw counters have no width");
        assert!(r.create("x", "queue", "bogus", plain()).is_err());
        assert!(r.create("x", "stack", "lcrq", plain()).is_err());
        assert!(r.create("", "counter", "elastic", plain()).is_err());
        assert!(r.create("a b", "counter", "elastic", plain()).is_err());
        assert!(r.create(&"n".repeat(65), "counter", "elastic", plain()).is_err());
        // Queues have no priority path, so no direct quota either —
        // neither as an explicit option nor as a spec segment.
        let opts = CreateOpts { direct_quota: Some(1), ..CreateOpts::default() };
        assert!(r.create("x", "queue", "lcrq+elastic", opts).is_err());
        assert!(r.create("x", "queue", "lcrq+elastic:aimd:d2", plain()).is_err());
        assert!(r.create("x", "queue", "lcrq+aggfunnel:4:d1", plain()).is_err());
    }

    #[test]
    fn counter_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("c", "counter", "elastic:fixed:2", CreateOpts::width(Some(6))).unwrap();
        assert_eq!(e.take(0, 5, false).unwrap(), 0);
        assert_eq!(e.take(1, 1, true).unwrap(), 5);
        assert_eq!(e.read(0).unwrap(), 6);
        assert!(e.enqueue(0, 1).is_err(), "counters reject queue ops");
        assert!(e.dequeue(0).is_err());
        let (width, previous) = e.resize(4).unwrap();
        assert_eq!((width, previous), (4, 2));
        assert_eq!(e.resize(100).unwrap().0, 6, "clamped to the max_width override");
        assert_eq!(e.set_policy(WidthPolicy::Fixed(3)).unwrap(), 3);
        let stats = e.stats_json();
        assert_eq!(stats.get("take").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-3"));
        assert_eq!(stats.get("kind").and_then(Json::as_str), Some("counter"));
    }

    #[test]
    fn direct_quota_gates_priority_takes() {
        let r = Registry::new(4);
        // Quota 0: every priority take demotes to the funnel path.
        let e = r.create("c", "counter", "elastic:fixed:2:d0", plain()).unwrap();
        assert_eq!(e.backend, "elastic:fixed:2:d0", "quota survives in the label");
        assert_eq!(e.direct_quota(), Some(0));
        assert_eq!(e.take(0, 3, true).unwrap(), 0);
        assert_eq!(e.take(1, 2, true).unwrap(), 3);
        let stats = e.stats_json();
        assert_eq!(stats.get("take_priority_demoted").and_then(Json::as_u64), Some(2));
        assert!(stats.get("take_priority").is_none(), "nothing went direct");
        assert_eq!(stats.get("direct_quota").and_then(Json::as_u64), Some(0));

        // An explicit option wins over the spec segment and shows up
        // in the canonical backend label.
        let opts = CreateOpts { direct_quota: Some(2), ..CreateOpts::default() };
        let e2 = r.create("c2", "counter", "elastic:aimd:d0", opts).unwrap();
        assert_eq!(e2.backend, "elastic:aimd:d2");
        assert_eq!(e2.direct_quota(), Some(2));
        assert_eq!(e2.take(0, 1, true).unwrap(), 0);
        let stats = e2.stats_json();
        assert_eq!(stats.get("take_priority").and_then(Json::as_u64), Some(1));
        assert!(stats.get("take_priority_demoted").is_none());

        // Unlimited (no quota) keeps the pre-quota behaviour.
        let e3 = r.create("c3", "counter", "elastic:aimd", plain()).unwrap();
        assert_eq!(e3.direct_quota(), None);
        e3.take(0, 1, true).unwrap();
        assert!(e3.stats_json().get("direct_quota").is_none());
    }

    #[test]
    fn concurrent_create_delete_same_name_is_safe() {
        // The shard refactor must not regress registry races: hammer
        // one name with create/delete from several threads; every op
        // must either succeed or fail cleanly, and the final state
        // must be coherent.
        let r = Arc::new(Registry::new(4));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut created = 0u64;
                    let mut deleted = 0u64;
                    for i in 0..200 {
                        if (t + i) % 2 == 0 {
                            if r.create("contested", "counter", "elastic:aimd", plain()).is_ok()
                            {
                                created += 1;
                            }
                        } else if r.remove("contested").is_ok() {
                            deleted += 1;
                        }
                    }
                    (created, deleted)
                })
            })
            .collect();
        let (mut created, mut deleted) = (0, 0);
        for t in threads {
            let (c, d) = t.join().unwrap();
            created += c;
            deleted += d;
        }
        let live = r.get("contested").is_ok();
        assert_eq!(created, deleted + live as u64, "creates balance deletes + survivor");
        assert_eq!(r.len(), live as usize);
    }

    #[test]
    fn delete_while_enqueue_in_flight_is_safe() {
        // A data-plane op holds its own Arc: deleting the object under
        // it must not invalidate the queue mid-operation, and items
        // already enqueued through the doomed handle stay readable
        // through that handle.
        let r = Arc::new(Registry::new(4));
        r.create("doomed", "queue", "lcrq+elastic:fixed:2", plain()).unwrap();
        let entry = r.get("doomed").unwrap();
        let writer = {
            let entry = Arc::clone(&entry);
            std::thread::spawn(move || {
                let mut sent = 0u64;
                for i in 0..500u64 {
                    entry.enqueue(1, i).unwrap();
                    sent += 1;
                }
                sent
            })
        };
        // Race the delete into the middle of the enqueue storm.
        while r.remove("doomed").is_err() {
            std::hint::spin_loop();
        }
        let sent = writer.join().unwrap();
        assert_eq!(sent, 500, "enqueues on a held Arc survive the delete");
        assert!(r.get("doomed").is_err(), "name is gone from the registry");
        let mut drained = 0u64;
        while entry.dequeue(0).unwrap().is_some() {
            drained += 1;
        }
        assert_eq!(drained, sent, "no items lost to the race");
    }

    #[test]
    fn queue_entry_ops() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+elastic:fixed:2", plain()).unwrap();
        assert_eq!(e.dequeue(0).unwrap(), None);
        e.enqueue(0, 7).unwrap();
        e.enqueue(1, 8).unwrap();
        assert_eq!(e.dequeue(1).unwrap(), Some(7));
        assert!(e.take(0, 1, false).is_err(), "queues reject counter ops");
        assert!(e.read(0).is_err());
        assert!(e.enqueue(0, EMPTY_ITEM).is_err(), "sentinel rejected");
        let (width, previous) = e.resize(3).unwrap();
        assert_eq!((width, previous), (3, 2));
        e.poll(); // controller tick must not panic
        let stats = e.stats_json();
        assert_eq!(stats.get("enqueue").and_then(Json::as_u64), Some(2));
        assert_eq!(stats.get("dequeue").and_then(Json::as_u64), Some(2));
        assert_eq!(stats.get("dequeue_empty").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert!(stats.get("index_cells").and_then(Json::as_u64).unwrap() >= 2);
        assert!(stats.get("main_faas").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn queue_max_width_override_applies() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+elastic:aimd", CreateOpts::width(Some(20))).unwrap();
        assert_eq!(e.resize(100).unwrap().0, 20, "clamped to the create-time override");
        let stats = e.stats_json();
        assert_eq!(stats.get("max_width").and_then(Json::as_u64), Some(20));
    }

    #[test]
    fn non_elastic_queue_has_no_width_controls() {
        let r = Registry::new(2);
        let e = r.create("q", "queue", "lcrq+hw", plain()).unwrap();
        e.enqueue(0, 1).unwrap();
        assert!(e.resize(2).is_err());
        assert!(e.set_policy(WidthPolicy::SqrtP).is_err());
        e.poll(); // still a no-op, not an error
        let stats = e.stats_json();
        assert!(stats.get("active_width").is_none());
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("lcrq+hw"));
    }

    #[test]
    fn aggfunnel_counter_spec_pins_width() {
        let r = Registry::new(2);
        let e = r.create("c", "counter", "aggfunnel:3", plain()).unwrap();
        let stats = e.stats_json();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-3"));
    }
}
