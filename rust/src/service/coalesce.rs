//! Executor-level op coalescing: one funnel op per sweep group.
//!
//! The paper's core batching insight — many fetch&adds can ride one
//! hardware FAA if they aggregate — applies one tier up as well. An
//! executor sweep already holds many connections' decoded requests;
//! this module groups them by (object, op-kind) and executes each
//! group as ONE merged backend op:
//!
//! * `take k₁ … take kₙ` on one counter become `take Σkᵢ`, and the
//!   granted range is sliced back per request — dense, disjoint, in
//!   pending order (the grant arithmetic is the pure
//!   [`grant_slices`] helper, property-tested below).
//! * same-object `enqueue`/`push` item lists concatenate into one
//!   batch insert (one write-ahead WAL record where there were n);
//! * `dequeue k` / `pop k` merge into one batch remove whose items
//!   are dealt back per request in pending order;
//! * `read`s share one linearizable read (all members linearize at
//!   the same point — a legal linearization, and the value each
//!   member reports is identical).
//!
//! **Merge rules.** Scanning the sweep plan in order, an op joins the
//! current group only if it targets the same object (same
//! [`ObjectEntry`] instance) with the same kind — and, for `take`,
//! the same `priority` class, so the §4.4 direct-quota gate is taken
//! once per group. Anything else — a different object, a different
//! kind, a control-plane op, a malformed request, an op owned by
//! another shard — closes the group. Groups are therefore *contiguous
//! runs* of the plan, which is what makes the merge safe: replies are
//! emitted in arrival order per connection, and two ops of one
//! connection can only merge if no other op of that connection sits
//! between them, so each connection's ops take effect in the order it
//! pipelined them.
//!
//! **Fallback is the byte-identical slow path.** Classification is
//! conservative: anything it does not fully recognise (unknown op,
//! parse error, out-of-range count, invalid item, wrong object kind,
//! forwarded name) is a passthrough executed by the ordinary
//! [`super::handle_request`] / [`super::handle_binary`] handlers, so
//! error replies and cross-shard behaviour cannot drift from the
//! uncoalesced wire contract. Groups of size 1 run through the same
//! merged entry points (they are equivalent to the per-op path) but
//! only groups of ≥ 2 count toward the `coalesce_*` stats.

use std::sync::Arc;

use crate::util::json::Json;

use super::conn::Request;
use super::error::{code_of, error_json, service_err, ErrorCode};
use super::frame::{self, BinRequest, BinResponse, Item};
use super::registry::ObjectEntry;
use super::{ServerState, DEFAULT_OBJECT, MAX_TAKE_COUNT};

/// How one decoded request executes: merged (with which parameters)
/// or through the ordinary per-op handlers.
enum Class {
    /// Execute via `handle_request`/`handle_binary`, byte-identical
    /// to the uncoalesced path. Also the home of `Overlong` and
    /// `BadFrame` pseudo-requests.
    Pass,
    Take { entry: Arc<ObjectEntry>, count: u64, priority: bool, bin: bool },
    Read { entry: Arc<ObjectEntry>, bin: bool },
    /// `enqueue`/`push` (which one is implied by the entry's kind —
    /// wrong-kind ops never classify). `count` remembers
    /// `items.len()` for the reply, since the items themselves drain
    /// into the merged batch before replies are built.
    Add { entry: Arc<ObjectEntry>, items: Vec<Item>, count: usize, shape: AddShape },
    /// `dequeue`/`pop`.
    Remove { entry: Arc<ObjectEntry>, want: u64, shape: RemShape },
}

/// Which reply the member expects for an insert.
#[derive(Clone, Copy, PartialEq)]
enum AddShape {
    /// JSON `item`/`data` spelling → `{"ok":true}`.
    JsonSingle,
    /// JSON `items` spelling → `ok` + `count`.
    JsonBatch,
    /// Binary frame → `Enqueued(n)` / `Pushed(n)`.
    Bin,
}

/// Which reply the member expects for a remove.
#[derive(Clone, Copy, PartialEq)]
enum RemShape {
    /// JSON legacy single form → `ok`+`item` / `ok`+`data` /
    /// `ok`+`empty`.
    JsonLegacy,
    /// JSON `count` form → `ok` + `count` + `items`.
    JsonBatch,
    /// Binary frame → `Items(..)` / `Popped(..)`.
    Bin,
}

/// A rendered-or-renderable reply for one plan slot.
enum Outcome {
    /// Not produced yet (or already rendered and taken).
    Missing,
    /// A JSON reply line (serialized at render time into the shared
    /// scratch string — no per-reply `String`).
    Json(Json),
    /// A binary response to encode at render time.
    Bin(BinResponse),
    /// An already-encoded binary response payload (the passthrough
    /// `handle_binary` contract).
    BinRaw(Vec<u8>),
}

/// Per-executor reusable sweep state: the drained plan, its
/// classification, the merged-execution outcomes, and the emission
/// buffers. One `Scratch` lives for the whole life of an executor
/// thread, so the steady-state sweep does not allocate.
pub(super) struct Scratch {
    plan: Vec<Request>,
    classes: Vec<Class>,
    outcomes: Vec<Outcome>,
    /// Frame-payload emission buffer.
    payload: Vec<u8>,
    /// JSON emission buffer.
    jbuf: String,
    /// Per-connection reply bytes (the slice `render_span` returns).
    out: Vec<u8>,
}

impl Scratch {
    pub(super) fn new() -> Self {
        Scratch {
            plan: Vec::new(),
            classes: Vec::new(),
            outcomes: Vec::new(),
            payload: Vec::new(),
            jbuf: String::new(),
            out: Vec::new(),
        }
    }

    /// Start a new sweep (keeps every allocation).
    pub(super) fn begin(&mut self) {
        self.plan.clear();
        self.classes.clear();
        self.outcomes.clear();
    }

    /// Append one drained request to the sweep plan.
    pub(super) fn push(&mut self, req: Request) {
        self.plan.push(req);
    }

    /// Ops in the current plan.
    pub(super) fn len(&self) -> usize {
        self.plan.len()
    }

    /// Render the replies for plan slots `start..end` (one
    /// connection's share, in arrival order) into the reusable output
    /// buffer and return it.
    pub(super) fn render_span(&mut self, start: usize, end: usize) -> &[u8] {
        let Scratch { outcomes, payload, jbuf, out, .. } = self;
        out.clear();
        for slot in outcomes.iter_mut().take(end).skip(start) {
            let outcome = std::mem::replace(slot, Outcome::Missing);
            match outcome {
                Outcome::Json(json) => {
                    jbuf.clear();
                    json.write_into(jbuf);
                    out.extend_from_slice(jbuf.as_bytes());
                    out.push(b'\n');
                }
                Outcome::Bin(resp) => {
                    payload.clear();
                    frame::encode_response(&resp, payload);
                    frame::encode_frame(payload, out);
                }
                Outcome::BinRaw(p) => frame::encode_frame(&p, out),
                Outcome::Missing => {
                    // Unreachable by construction (every plan slot
                    // gets exactly one outcome); answer *something*
                    // rather than break the one-reply-per-request
                    // pipelining contract.
                    debug_assert!(false, "plan slot without an outcome");
                    jbuf.clear();
                    error_json(&service_err(ErrorCode::Protocol, "lost reply"))
                        .write_into(jbuf);
                    out.extend_from_slice(jbuf.as_bytes());
                    out.push(b'\n');
                }
            }
        }
        out
    }

    /// Hand the sweep's request buffers back (for the connection
    /// layer to recycle into its pool). Call after every span has
    /// been rendered.
    pub(super) fn drain_plan(&mut self) -> std::vec::Drain<'_, Request> {
        self.plan.drain(..)
    }
}

/// Classify and execute the whole sweep plan, leaving one outcome per
/// plan slot. `via` is the shard whose executor is running (`tid` its
/// funnel tid); with `enabled` false everything passes through the
/// ordinary handlers (the coalescing-off baseline).
pub(super) fn execute_sweep(
    state: &ServerState,
    via: usize,
    tid: usize,
    enabled: bool,
    scratch: &mut Scratch,
) {
    let Scratch { plan, classes, outcomes, .. } = scratch;
    for req in plan.iter() {
        classes.push(if enabled { classify(state, via, req) } else { Class::Pass });
        outcomes.push(Outcome::Missing);
    }
    let n = plan.len();
    let mut i = 0;
    while i < n {
        if matches!(classes[i], Class::Pass) {
            outcomes[i] = run_pass(state, via, tid, &plan[i]);
            i += 1;
            continue;
        }
        // A maximal run of ops that merge with plan[i]: same object,
        // same kind (and priority class for takes).
        let mut j = i + 1;
        while j < n && same_group(&classes[i], &classes[j]) {
            j += 1;
        }
        run_group(state, via, tid, classes, outcomes, i, j);
        i = j;
    }
}

/// May `b` join a group whose first member is `a`?
fn same_group(a: &Class, b: &Class) -> bool {
    match (a, b) {
        (
            Class::Take { entry: ea, priority: pa, .. },
            Class::Take { entry: eb, priority: pb, .. },
        ) => Arc::ptr_eq(ea, eb) && pa == pb,
        (Class::Read { entry: ea, .. }, Class::Read { entry: eb, .. }) => Arc::ptr_eq(ea, eb),
        (Class::Add { entry: ea, .. }, Class::Add { entry: eb, .. }) => Arc::ptr_eq(ea, eb),
        (Class::Remove { entry: ea, .. }, Class::Remove { entry: eb, .. }) => {
            Arc::ptr_eq(ea, eb)
        }
        _ => false,
    }
}

/// Execute one passthrough op exactly as the pre-coalescing executor
/// did.
fn run_pass(state: &ServerState, via: usize, tid: usize, req: &Request) -> Outcome {
    match req {
        Request::Line(line) => Outcome::Json(
            match super::handle_request(state, via, tid, line) {
                Ok(json) => json,
                Err(e) => error_json(&e),
            },
        ),
        Request::Overlong(len) => Outcome::Json(error_json(&service_err(
            ErrorCode::Protocol,
            format!(
                "request line exceeds {} bytes ({len} received)",
                super::conn::MAX_LINE
            ),
        ))),
        Request::Frame(payload) => {
            Outcome::BinRaw(super::handle_binary(state, via, tid, payload))
        }
        Request::BadFrame(msg) => Outcome::Bin(BinResponse::Err {
            code: ErrorCode::Protocol,
            msg: msg.clone(),
        }),
    }
}

/// Execute the merged group covering plan slots `start..end`.
fn run_group(
    state: &ServerState,
    via: usize,
    tid: usize,
    classes: &mut [Class],
    outcomes: &mut [Outcome],
    start: usize,
    end: usize,
) {
    let members = (end - start) as u64;
    let shard = &state.shards[via];
    // The classified ops skip `handle_request`/`handle_binary`, which
    // would each have counted one request.
    shard.metrics.add("requests", members);
    if members >= 2 {
        shard.metrics.add("coalesced_ops", members);
        shard.metrics.incr("coalesce_merges");
        shard.metrics.incr(batch_bucket(members));
    }
    match &classes[start] {
        Class::Pass => unreachable!("passthroughs never open a group"),
        Class::Take { entry, priority, .. } => {
            let entry = Arc::clone(entry);
            let priority = *priority;
            let mut total = 0u64;
            for c in &classes[start..end] {
                let Class::Take { count, .. } = c else { unreachable!() };
                total += count; // counts ≤ 2³², run length is sweep-bounded
            }
            match entry.take_merged(tid, total, members, priority) {
                Ok(grant) => {
                    let mut at = grant;
                    for i in start..end {
                        let Class::Take { count, bin, .. } = &classes[i] else {
                            unreachable!()
                        };
                        outcomes[i] = if *bin {
                            Outcome::Bin(BinResponse::Start(at))
                        } else {
                            Outcome::Json(Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("start", Json::num(at as f64)),
                                ("count", Json::num(*count as f64)),
                            ]))
                        };
                        at += count;
                    }
                }
                Err(e) => fail_group(&e, classes, outcomes, start, end),
            }
        }
        Class::Read { entry, .. } => {
            let entry = Arc::clone(entry);
            match entry.read_merged(tid, members) {
                Ok(value) => {
                    for i in start..end {
                        let Class::Read { bin, .. } = &classes[i] else { unreachable!() };
                        outcomes[i] = if *bin {
                            Outcome::Bin(BinResponse::Value(value))
                        } else {
                            Outcome::Json(Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("value", Json::num(value as f64)),
                            ]))
                        };
                    }
                }
                Err(e) => fail_group(&e, classes, outcomes, start, end),
            }
        }
        Class::Add { entry, .. } => {
            let entry = Arc::clone(entry);
            let lifo = entry.kind() == "stack";
            let mut batch: Vec<Item> = Vec::new();
            for c in classes[start..end].iter_mut() {
                let Class::Add { items, .. } = c else { unreachable!() };
                if batch.is_empty() {
                    // The common single-member group moves, not copies.
                    batch = std::mem::take(items);
                } else {
                    batch.append(items);
                }
            }
            let result = if lifo {
                entry.push_merged(tid, batch)
            } else {
                entry.enqueue_merged(tid, batch)
            };
            match result {
                Ok(()) => {
                    for i in start..end {
                        let Class::Add { count, shape, .. } = &classes[i] else {
                            unreachable!()
                        };
                        outcomes[i] = match shape {
                            AddShape::JsonSingle => {
                                Outcome::Json(Json::obj(vec![("ok", Json::Bool(true))]))
                            }
                            AddShape::JsonBatch => Outcome::Json(Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("count", Json::num(*count as f64)),
                            ])),
                            AddShape::Bin if lifo => {
                                Outcome::Bin(BinResponse::Pushed(*count as u32))
                            }
                            AddShape::Bin => {
                                Outcome::Bin(BinResponse::Enqueued(*count as u32))
                            }
                        };
                    }
                }
                Err(e) => fail_group(&e, classes, outcomes, start, end),
            }
        }
        Class::Remove { entry, .. } => {
            let entry = Arc::clone(entry);
            let lifo = entry.kind() == "stack";
            let mut total = 0u64;
            for c in &classes[start..end] {
                let Class::Remove { want, .. } = c else { unreachable!() };
                total += want;
            }
            let result = if lifo {
                entry.pop_merged(tid, total)
            } else {
                entry.dequeue_merged(tid, total)
            };
            match result {
                Ok(got) => {
                    let mut dealt = got.into_iter();
                    for i in start..end {
                        let Class::Remove { want, shape, .. } = &classes[i] else {
                            unreachable!()
                        };
                        let mine: Vec<Item> =
                            dealt.by_ref().take(*want as usize).collect();
                        outcomes[i] = match shape {
                            RemShape::JsonLegacy => {
                                Outcome::Json(match mine.into_iter().next() {
                                    Some(Item::Int(item)) => Json::obj(vec![
                                        ("ok", Json::Bool(true)),
                                        ("item", Json::num(item as f64)),
                                    ]),
                                    Some(Item::Bytes(b)) => Json::obj(vec![
                                        ("ok", Json::Bool(true)),
                                        ("data", Json::str(frame::to_hex(&b))),
                                    ]),
                                    None => Json::obj(vec![
                                        ("ok", Json::Bool(true)),
                                        ("empty", Json::Bool(true)),
                                    ]),
                                })
                            }
                            RemShape::JsonBatch => Outcome::Json(Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("count", Json::num(mine.len() as f64)),
                                ("items", Json::arr(mine.iter().map(Item::to_json))),
                            ])),
                            RemShape::Bin if lifo => Outcome::Bin(BinResponse::Popped(mine)),
                            RemShape::Bin => Outcome::Bin(BinResponse::Items(mine)),
                        };
                    }
                }
                Err(e) => fail_group(&e, classes, outcomes, start, end),
            }
        }
    }
}

/// Render the same failure to every member of a group, per its wire.
/// `anyhow::Error` is not `Clone`, so each member renders from the
/// one borrowed error.
fn fail_group(
    e: &anyhow::Error,
    classes: &[Class],
    outcomes: &mut [Outcome],
    start: usize,
    end: usize,
) {
    for i in start..end {
        let bin = match &classes[i] {
            Class::Take { bin, .. } | Class::Read { bin, .. } => *bin,
            Class::Add { shape, .. } => *shape == AddShape::Bin,
            Class::Remove { shape, .. } => *shape == RemShape::Bin,
            Class::Pass => false,
        };
        outcomes[i] = if bin {
            Outcome::Bin(BinResponse::Err { code: code_of(e), msg: e.to_string() })
        } else {
            Outcome::Json(error_json(e))
        };
    }
}

/// The merged-batch size histogram bucket (powers-of-two ranges).
fn batch_bucket(n: u64) -> &'static str {
    match n {
        0..=3 => "coalesce_b2",
        4..=7 => "coalesce_b4",
        8..=15 => "coalesce_b8",
        16..=31 => "coalesce_b16",
        _ => "coalesce_b32",
    }
}

/// Classify one decoded request. Conservative: anything not fully
/// recognised as a same-shard, well-formed data-plane op on an
/// existing object of the right kind passes through the ordinary
/// handlers (whose error replies stay byte-identical).
fn classify(state: &ServerState, via: usize, req: &Request) -> Class {
    match req {
        Request::Line(line) => classify_line(state, via, line),
        Request::Frame(payload) => classify_frame(state, via, payload),
        Request::Overlong(_) | Request::BadFrame(_) => Class::Pass,
    }
}

fn classify_line(state: &ServerState, via: usize, line: &str) -> Class {
    let Ok(req) = Json::parse(line) else { return Class::Pass };
    let Some(op) = req.get("op").and_then(Json::as_str) else { return Class::Pass };
    if !matches!(op, "take" | "read" | "enqueue" | "dequeue" | "push" | "pop") {
        return Class::Pass;
    }
    let name = req.get("name").and_then(Json::as_str).unwrap_or(DEFAULT_OBJECT);
    // `stats` with name "*" never reaches here (op filter above), so
    // plain ownership is the only routing question. `shard_for`, not
    // `route`: a forwarded op passes through and `route` counts the
    // hop exactly once, in `handle_request`.
    let owner = state.shard_for(name);
    if owner.index != via {
        return Class::Pass;
    }
    let Ok(entry) = owner.registry.get(name) else { return Class::Pass };
    match op {
        "take" => {
            if entry.kind() != "counter" {
                return Class::Pass;
            }
            let count = req.get("count").and_then(Json::as_u64).unwrap_or(1).max(1);
            if count > MAX_TAKE_COUNT {
                return Class::Pass;
            }
            let priority = req.get("priority").and_then(Json::as_bool).unwrap_or(false);
            Class::Take { entry, count, priority, bin: false }
        }
        "read" => {
            if entry.kind() != "counter" {
                return Class::Pass;
            }
            Class::Read { entry, bin: false }
        }
        "enqueue" | "push" => {
            let wanted = if op == "enqueue" { "queue" } else { "stack" };
            if entry.kind() != wanted {
                return Class::Pass;
            }
            let (items, shape) = if let Some(arr) = req.get("items").and_then(Json::as_arr) {
                if arr.len() > frame::MAX_BATCH_ITEMS {
                    return Class::Pass;
                }
                let mut items = Vec::with_capacity(arr.len());
                for v in arr {
                    let Some(item) = Item::from_json(v) else { return Class::Pass };
                    items.push(item);
                }
                (items, AddShape::JsonBatch)
            } else if let Some(hex) = req.get("data").and_then(Json::as_str) {
                let Some(bytes) = frame::from_hex(hex) else { return Class::Pass };
                (vec![Item::Bytes(bytes)], AddShape::JsonSingle)
            } else if let Some(item) = req.get("item").and_then(Json::as_u64) {
                (vec![Item::Int(item)], AddShape::JsonSingle)
            } else {
                return Class::Pass;
            };
            // Pre-validate so the merged execution cannot fail on one
            // member's payload: an invalid item keeps its request on
            // the slow path and its error reply byte-identical.
            for item in &items {
                if entry.validate_item(item).is_err() {
                    return Class::Pass;
                }
            }
            let count = items.len();
            Class::Add { entry, items, count, shape }
        }
        "dequeue" | "pop" => {
            let wanted = if op == "dequeue" { "queue" } else { "stack" };
            if entry.kind() != wanted {
                return Class::Pass;
            }
            match req.get("count").and_then(Json::as_u64) {
                Some(c) if c == 0 || c > frame::MAX_BATCH_ITEMS as u64 => Class::Pass,
                Some(c) => Class::Remove { entry, want: c, shape: RemShape::JsonBatch },
                None => Class::Remove { entry, want: 1, shape: RemShape::JsonLegacy },
            }
        }
        _ => Class::Pass,
    }
}

fn classify_frame(state: &ServerState, via: usize, payload: &[u8]) -> Class {
    let Ok(req) = frame::decode_request(payload) else { return Class::Pass };
    let name = match &req {
        // Control frames and undecodable payloads re-decode on the
        // passthrough (cold) path.
        BinRequest::Json(_) => return Class::Pass,
        BinRequest::Take { name, .. }
        | BinRequest::Read { name }
        | BinRequest::Enqueue { name, .. }
        | BinRequest::Dequeue { name, .. }
        | BinRequest::Push { name, .. }
        | BinRequest::Pop { name, .. } => name,
    };
    let owner = state.shard_for(name);
    if owner.index != via {
        return Class::Pass;
    }
    let Ok(entry) = owner.registry.get(name) else { return Class::Pass };
    match req {
        BinRequest::Json(_) => Class::Pass,
        BinRequest::Take { count, priority, .. } => {
            if entry.kind() != "counter" {
                return Class::Pass;
            }
            // `decode_request` already bounded the count; zero means
            // one, as in the JSON spelling.
            Class::Take { entry, count: count.max(1), priority, bin: true }
        }
        BinRequest::Read { .. } => {
            if entry.kind() != "counter" {
                return Class::Pass;
            }
            Class::Read { entry, bin: true }
        }
        BinRequest::Enqueue { items, .. } => {
            if entry.kind() != "queue" {
                return Class::Pass;
            }
            for item in &items {
                if entry.validate_item(item).is_err() {
                    return Class::Pass;
                }
            }
            let count = items.len();
            Class::Add { entry, items, count, shape: AddShape::Bin }
        }
        BinRequest::Push { items, .. } => {
            if entry.kind() != "stack" {
                return Class::Pass;
            }
            for item in &items {
                if entry.validate_item(item).is_err() {
                    return Class::Pass;
                }
            }
            let count = items.len();
            Class::Add { entry, items, count, shape: AddShape::Bin }
        }
        BinRequest::Dequeue { count, .. } => {
            if entry.kind() != "queue" {
                return Class::Pass;
            }
            Class::Remove { entry, want: count as u64, shape: RemShape::Bin }
        }
        BinRequest::Pop { count, .. } => {
            if entry.kind() != "stack" {
                return Class::Pass;
            }
            Class::Remove { entry, want: count as u64, shape: RemShape::Bin }
        }
    }
}

/// The merged-take grant arithmetic, pure for property testing: slice
/// `[start, start + Σcounts)` back per member, in order.
#[cfg(test)]
fn grant_slices(start: u64, counts: &[u64]) -> Vec<(u64, u64)> {
    let mut out = Vec::with_capacity(counts.len());
    let mut at = start;
    for &c in counts {
        out.push((at, c));
        at += c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper-facing exactness property: however takes interleave
    /// into a merged batch, the sliced grants are dense (no gap),
    /// disjoint (no overlap), and order-consistent (member i's range
    /// precedes member i+1's). Randomized over many batch shapes with
    /// a deterministic xorshift so failures replay.
    #[test]
    fn merged_take_grants_are_dense_disjoint_and_ordered() {
        let mut seed = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for _ in 0..500 {
            let members = (next() % 64 + 1) as usize;
            let start = next() % (1 << 40);
            let counts: Vec<u64> = (0..members).map(|_| next() % 1000 + 1).collect();
            let total: u64 = counts.iter().sum();
            let grants = grant_slices(start, &counts);
            assert_eq!(grants.len(), members);
            let mut at = start;
            for (i, (s, c)) in grants.iter().enumerate() {
                assert_eq!(*s, at, "grant {i} must start where the previous ended");
                assert_eq!(*c, counts[i], "grant {i} keeps its requested count");
                at = s + c;
            }
            assert_eq!(at, start + total, "grants tile the merged range exactly");
        }
    }

    #[test]
    fn batch_buckets_partition_sizes() {
        assert_eq!(batch_bucket(2), "coalesce_b2");
        assert_eq!(batch_bucket(3), "coalesce_b2");
        assert_eq!(batch_bucket(4), "coalesce_b4");
        assert_eq!(batch_bucket(7), "coalesce_b4");
        assert_eq!(batch_bucket(8), "coalesce_b8");
        assert_eq!(batch_bucket(16), "coalesce_b16");
        assert_eq!(batch_bucket(31), "coalesce_b16");
        assert_eq!(batch_bucket(32), "coalesce_b32");
        assert_eq!(batch_bucket(10_000), "coalesce_b32");
    }
}
