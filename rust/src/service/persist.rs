//! Durability for the registry service: per-shard write-ahead logs
//! and compacted snapshots.
//!
//! Every [`super::Shard`] that serves a persistent registry owns one
//! [`ShardLog`]: an append-only WAL of **logical** mutation records
//! (object creations and deletions, post-batch counter values, queue
//! item multiset deltas) plus a periodically rewritten snapshot that
//! compacts the log. Boot-time recovery loads the snapshot, replays
//! the WAL tail on top of it, and hands the resulting
//! [`RecoveryModel`] back to the service, which re-creates every
//! object through the ordinary [`crate::faa::BackendSpec`] path and
//! seeds counters and queues before the listener starts serving.
//!
//! Three disciplines keep this correct without touching the lock-free
//! hot path:
//!
//! * **Logical records, not funnel internals.** A counter record is
//!   the *post-batch counter value* (`max` on replay), never the
//!   per-thread funnel state; a queue record is an item list delta
//!   (integers or byte strings — see [`super::frame::Item`]).
//!   Replay therefore never needs to reconstruct Aggregator or ring
//!   state — it re-creates objects from their backend spec and seeds
//!   them, exactly as a fresh `create` would.
//! * **Append-then-publish.** Records are framed
//!   (`len ‖ fnv1a64 checksum ‖ payload` — the [`super::frame`] codec
//!   the binary wire protocol also speaks, so disk and wire share one
//!   format) and appended before they count; snapshots are written to
//!   `snapshot.json.tmp`, fsynced,
//!   and `rename`d into place, so a reader never observes a partially
//!   written snapshot (the atomic-state-update discipline of
//!   `atomic-try-update`). A torn WAL tail is detected by the frame
//!   checksums and truncated on recovery.
//! * **Replay-idempotent records.** Every record carries a
//!   monotonically increasing sequence number and the snapshot
//!   records the last sequence it covers; replay skips records the
//!   snapshot already absorbed, so a crash between "snapshot
//!   published" and "WAL truncated" cannot double-apply an enqueue.
//!
//! Group commit mirrors the paper's batching argument: with
//! `fsync_interval_ms > 0` the mutation hot path only bumps a
//! per-object high-water mark (counters, one lock-free `fetch_max`)
//! or pushes onto a lock-free [`ClaimStack`] (queues and stacks) —
//! no mutex, no spinlock, anywhere on the ack path. A flusher thread
//! **claims** each journal's pending window (one 128-bit CAS swaps
//! the whole batch out, exactly once, in push order) and coalesces it
//! into **one record per object per interval** — one WAL append per
//! aggregated batch of operations, not one per op, just as the funnel
//! pays one hardware F&A per batch. Deleting an object *closes* its
//! claim stacks (same CAS word), so a late op on a held handle is
//! rejected atomically instead of leaking into a re-created object —
//! the claim epoch replaces the lock ordering the old spinlocked
//! buffer needed. `fsync_interval_ms = 0` selects synchronous mode:
//! every mutation appends (and syncs) its record before the response
//! is acked, which is what the crash-recovery tests run under.

use std::collections::{BTreeMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::frame::{decode_frames, encode_frame, Item};
use super::ServerState;
use crate::sync::ClaimStack;
use crate::util::json::Json;

/// Largest value the durable layer represents exactly: WAL records
/// and snapshots go through the JSON model (`f64`-backed), which is
/// exact only below 2⁵³. Persisted queues reject bigger items at
/// enqueue (so an acked item can never round on recovery), and
/// recovery refuses counter seeds beyond it (a bigger value in a
/// snapshot is corruption, not data).
pub const MAX_DURABLE_ITEM: u64 = (1 << 53) - 1;

/// Snapshot and WAL file names inside a shard's directory.
const SNAPSHOT_FILE: &str = "snapshot.json";
const SNAPSHOT_TMP_FILE: &str = "snapshot.json.tmp";
const WAL_FILE: &str = "wal.log";

/// Durability configuration for [`super::serve`].
#[derive(Clone, Debug)]
pub struct PersistOpts {
    /// Root directory; shard `i` persists under `<data_dir>/shard-<i>`.
    pub data_dir: String,
    /// Group-commit interval in milliseconds: the flusher coalesces
    /// each interval's mutations into one WAL append (one record per
    /// object per interval) and syncs it. `0` = synchronous mode —
    /// every mutation appends its record before the response is
    /// acked (slowest, strongest: acked implies durable).
    pub fsync_interval_ms: u64,
    /// Snapshot rewrite period in milliseconds (`0` disables periodic
    /// snapshots; one is still written at boot, on graceful shutdown,
    /// and on the `snapshot` wire op).
    pub snapshot_interval_ms: u64,
}

impl Default for PersistOpts {
    fn default() -> Self {
        Self { data_dir: String::new(), fsync_interval_ms: 5, snapshot_interval_ms: 60_000 }
    }
}

impl PersistOpts {
    /// Group-commit persistence under `data_dir` with the default
    /// intervals.
    pub fn dir(data_dir: impl Into<String>) -> Self {
        Self { data_dir: data_dir.into(), ..Self::default() }
    }

    /// Synchronous persistence under `data_dir`: every mutation's
    /// record is on disk before the response is acked.
    pub fn sync(data_dir: impl Into<String>) -> Self {
        Self { data_dir: data_dir.into(), fsync_interval_ms: 0, ..Self::default() }
    }

    /// True when every mutation appends inline (no group commit).
    pub fn sync_mode(&self) -> bool {
        self.fsync_interval_ms == 0
    }
}

// ---------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------

/// One logical WAL record. Counter values are absolute post-batch
/// values (replay takes the max), queue and stack records are
/// item-multiset deltas; the §4.4 direct quota travels inside the
/// canonical backend label (`:d<k>`), so `Create` needs no extra
/// field for it.
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    Create { name: String, kind: String, backend: String, max_width: Option<usize> },
    Delete { name: String },
    /// Absolute counter value after an acked take (idempotent: replay
    /// keeps the maximum seen).
    Counter { name: String, value: u64 },
    Enqueue { name: String, items: Vec<Item> },
    Dequeue { name: String, items: Vec<Item> },
    /// Stack deltas: `Push` extends the top end, `Pop` removes the
    /// **latest** matching item (LIFO), where `Dequeue` removes the
    /// earliest.
    Push { name: String, items: Vec<Item> },
    Pop { name: String, items: Vec<Item> },
}

impl Record {
    /// Wire form: one compact JSON object carrying the sequence
    /// number assigned at append time.
    fn to_json(&self, seq: u64) -> Json {
        let mut pairs = vec![("s", Json::num(seq as f64))];
        match self {
            Record::Create { name, kind, backend, max_width } => {
                pairs.push(("t", Json::str("create")));
                pairs.push(("n", Json::str(name.clone())));
                pairs.push(("k", Json::str(kind.clone())));
                pairs.push(("b", Json::str(backend.clone())));
                if let Some(w) = max_width {
                    pairs.push(("w", Json::num(*w as f64)));
                }
            }
            Record::Delete { name } => {
                pairs.push(("t", Json::str("delete")));
                pairs.push(("n", Json::str(name.clone())));
            }
            Record::Counter { name, value } => {
                pairs.push(("t", Json::str("ctr")));
                pairs.push(("n", Json::str(name.clone())));
                pairs.push(("v", Json::num(*value as f64)));
            }
            Record::Enqueue { name, items } => {
                pairs.push(("t", Json::str("enq")));
                pairs.push(("n", Json::str(name.clone())));
                pairs.push(("i", Json::arr(items.iter().map(Item::to_json))));
            }
            Record::Dequeue { name, items } => {
                pairs.push(("t", Json::str("deq")));
                pairs.push(("n", Json::str(name.clone())));
                pairs.push(("i", Json::arr(items.iter().map(Item::to_json))));
            }
            Record::Push { name, items } => {
                pairs.push(("t", Json::str("psh")));
                pairs.push(("n", Json::str(name.clone())));
                pairs.push(("i", Json::arr(items.iter().map(Item::to_json))));
            }
            Record::Pop { name, items } => {
                pairs.push(("t", Json::str("pop")));
                pairs.push(("n", Json::str(name.clone())));
                pairs.push(("i", Json::arr(items.iter().map(Item::to_json))));
            }
        }
        Json::obj(pairs)
    }

    /// Parse a record payload back into `(seq, Record)`.
    fn from_json(j: &Json) -> Result<(u64, Record)> {
        let seq = j.get("s").and_then(Json::as_u64).ok_or_else(|| anyhow!("record missing seq"))?;
        let t = j.get("t").and_then(Json::as_str).ok_or_else(|| anyhow!("record missing type"))?;
        let name = || -> Result<String> {
            Ok(j.get("n")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("record missing name"))?
                .to_string())
        };
        let items = || -> Result<Vec<Item>> {
            j.get("i")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("record missing items"))?
                .iter()
                .map(|v| Item::from_json(v).ok_or_else(|| anyhow!("unparseable record item")))
                .collect()
        };
        let rec = match t {
            "create" => Record::Create {
                name: name()?,
                kind: j
                    .get("k")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("create record missing kind"))?
                    .to_string(),
                backend: j
                    .get("b")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("create record missing backend"))?
                    .to_string(),
                max_width: j.get("w").and_then(Json::as_u64).map(|w| w as usize),
            },
            "delete" => Record::Delete { name: name()? },
            "ctr" => Record::Counter {
                name: name()?,
                value: j
                    .get("v")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("counter record missing value"))?,
            },
            "enq" => Record::Enqueue { name: name()?, items: items()? },
            "deq" => Record::Dequeue { name: name()?, items: items()? },
            "psh" => Record::Push { name: name()?, items: items()? },
            "pop" => Record::Pop { name: name()?, items: items()? },
            other => return Err(anyhow!("unknown record type {other:?}")),
        };
        Ok((seq, rec))
    }
}

// ---------------------------------------------------------------------
// Recovery model
// ---------------------------------------------------------------------

/// The durable view of one object: enough to re-create it through the
/// backend-spec path and seed its contents.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObjectState {
    /// `"counter"`, `"queue"`, or `"stack"`.
    pub kind: String,
    /// Canonical backend spec (carries the `:d<k>` direct quota).
    pub backend: String,
    /// Create-time elastic slot-capacity override, if any (not part
    /// of the backend label, so persisted separately).
    pub max_width: Option<usize>,
    /// Counter value (counters only).
    pub counter: u64,
    /// Item contents (queues: oldest first; stacks: bottom to top).
    pub items: VecDeque<Item>,
}

/// The materialized state a snapshot stores and the WAL replays into:
/// object specs plus counter values and queue item lists. Also
/// maintained live by [`ShardLog::append`], so writing a snapshot
/// never has to inspect (or pause) the lock-free objects themselves.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveryModel {
    /// Sequence number of the last applied record.
    pub seq: u64,
    pub objects: BTreeMap<String, ObjectState>,
}

impl RecoveryModel {
    /// Apply one record. Idempotent across replays: records at or
    /// below the already-applied sequence are skipped, counter values
    /// only ever grow, and re-creating an existing object is a no-op
    /// (the live state wins over the spec record).
    pub fn apply(&mut self, seq: u64, rec: &Record) {
        if seq <= self.seq {
            return;
        }
        self.seq = seq;
        match rec {
            Record::Create { name, kind, backend, max_width } => {
                self.objects.entry(name.clone()).or_insert_with(|| ObjectState {
                    kind: kind.clone(),
                    backend: backend.clone(),
                    max_width: *max_width,
                    ..ObjectState::default()
                });
            }
            Record::Delete { name } => {
                self.objects.remove(name);
            }
            Record::Counter { name, value } => {
                if let Some(o) = self.objects.get_mut(name) {
                    o.counter = o.counter.max(*value);
                }
            }
            Record::Enqueue { name, items } => {
                if let Some(o) = self.objects.get_mut(name) {
                    o.items.extend(items.iter().cloned());
                }
            }
            Record::Dequeue { name, items } => {
                if let Some(o) = self.objects.get_mut(name) {
                    for item in items {
                        if let Some(i) = o.items.iter().position(|x| x == item) {
                            o.items.remove(i);
                        }
                    }
                }
            }
            Record::Push { name, items } => {
                if let Some(o) = self.objects.get_mut(name) {
                    o.items.extend(items.iter().cloned());
                }
            }
            Record::Pop { name, items } => {
                // LIFO removal: a pop takes the *latest* matching item
                // so duplicate values resolve toward the stack's top.
                if let Some(o) = self.objects.get_mut(name) {
                    for item in items {
                        if let Some(i) = o.items.iter().rposition(|x| x == item) {
                            o.items.remove(i);
                        }
                    }
                }
            }
        }
    }

    /// Serialize as the snapshot document.
    pub fn to_snapshot_json(&self) -> Json {
        let objects: BTreeMap<String, Json> = self
            .objects
            .iter()
            .map(|(name, o)| {
                let mut pairs = vec![
                    ("kind", Json::str(o.kind.clone())),
                    ("backend", Json::str(o.backend.clone())),
                    ("counter", Json::num(o.counter as f64)),
                    ("items", Json::arr(o.items.iter().map(Item::to_json))),
                ];
                if let Some(w) = o.max_width {
                    pairs.push(("max_width", Json::num(w as f64)));
                }
                (name.clone(), Json::obj(pairs))
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("seq", Json::num(self.seq as f64)),
            ("objects", Json::Obj(objects)),
        ])
    }

    /// Parse a snapshot document.
    pub fn from_snapshot_json(j: &Json) -> Result<RecoveryModel> {
        let version = j.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version != 1 {
            return Err(anyhow!("unsupported snapshot version {version}"));
        }
        let seq = j.get("seq").and_then(Json::as_u64).unwrap_or(0);
        let mut objects = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("objects") {
            for (name, o) in map {
                let field = |k: &str| -> Result<String> {
                    Ok(o.get(k)
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("snapshot object {name:?} missing {k}"))?
                        .to_string())
                };
                let items: VecDeque<Item> = o
                    .get("items")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| {
                        Item::from_json(v).ok_or_else(|| anyhow!("unparseable snapshot item"))
                    })
                    .collect::<Result<_>>()?;
                objects.insert(
                    name.clone(),
                    ObjectState {
                        kind: field("kind")?,
                        backend: field("backend")?,
                        max_width: o.get("max_width").and_then(Json::as_u64).map(|w| w as usize),
                        counter: o.get("counter").and_then(Json::as_u64).unwrap_or(0),
                        items,
                    },
                );
            }
        }
        Ok(RecoveryModel { seq, objects })
    }
}

// ---------------------------------------------------------------------
// Cluster layout pinning
// ---------------------------------------------------------------------

/// Check (or record, on first boot) the cluster layout under
/// `data_dir`. A shard's log is bound to its slice of the hash space,
/// so restarting the same directory with a different shard count
/// would silently strand every object whose name now hashes
/// elsewhere — refuse loudly instead (resharding needs a real
/// migration; see ROADMAP).
pub fn check_layout(data_dir: &Path, shards: usize) -> Result<()> {
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("creating data dir {}", data_dir.display()))?;
    let path = data_dir.join("layout.json");
    if path.exists() {
        let text = std::fs::read_to_string(&path)?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("corrupt layout file {}: {e}", path.display()))?;
        let recorded =
            json.get("shards").and_then(Json::as_u64).unwrap_or(0) as usize;
        if recorded != shards {
            return Err(anyhow!(
                "data_dir {} holds a {recorded}-shard cluster; booting it with {shards} \
                 shard(s) would strand hash-routed objects — keep the shard count or \
                 migrate the data",
                data_dir.display()
            ));
        }
        return Ok(());
    }
    let doc = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("shards", Json::num(shards as f64)),
        ("hash", Json::str(super::shard::SHARD_HASH_SCHEME)),
    ]);
    // Same publish discipline as snapshots (tmp → fsync → rename): a
    // crash during first boot must not leave a partial layout file
    // that blocks every later boot.
    let tmp = data_dir.join("layout.json.tmp");
    {
        let mut f =
            File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(doc.to_string().as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------
// The shard log
// ---------------------------------------------------------------------

/// What boot-time recovery found.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    /// Objects in the recovered model (snapshot + WAL tail).
    pub objects: usize,
    /// WAL records replayed on top of the snapshot.
    pub replayed: usize,
    /// Whether a torn/corrupt WAL tail was truncated.
    pub torn_tail: bool,
}

/// One shard's durability state: the WAL file, the live
/// [`RecoveryModel`] it folds into, and cumulative counters surfaced
/// through `stats`.
pub struct ShardLog {
    dir: PathBuf,
    sync: bool,
    inner: Mutex<LogInner>,
    /// Serializes whole drain+append cycles ([`flush_shard`]): two
    /// concurrent drains (the flusher racing the `snapshot` op) could
    /// otherwise split one journal's enqueue and dequeue buffers
    /// across two appends in the wrong order.
    drain_gate: Mutex<()>,
    /// Set when a failed append could not be rewound: the WAL may end
    /// in partial bytes, so no further frames may be appended behind
    /// them (see [`ShardLog::write_records`]).
    poisoned: std::sync::atomic::AtomicBool,
    recovery: RecoveryReport,
    wal_records: AtomicU64,
    wal_flushes: AtomicU64,
    wal_errors: AtomicU64,
    snapshots: AtomicU64,
    /// Claimed-stack journal telemetry (group-commit mode): lock-free
    /// pushes accepted, CAS failures those pushes burned, non-empty
    /// windows drained, and the drained-batch size tail (max + total
    /// items, total/drains = average batch).
    journal_pushes: AtomicU64,
    journal_cas_retries: AtomicU64,
    journal_drains: AtomicU64,
    journal_batch_items_max: AtomicU64,
    journal_batch_items_total: AtomicU64,
}

struct LogInner {
    wal: File,
    model: RecoveryModel,
    records_since_snapshot: u64,
}

impl ShardLog {
    /// Open (or create) a shard's durability directory: load the
    /// snapshot if present, replay the WAL tail, truncate any torn
    /// tail, and leave the WAL positioned for appends.
    pub fn open(dir: &Path, sync: bool) -> Result<ShardLog> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating data dir {}", dir.display()))?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let mut model = if snap_path.exists() {
            let text = std::fs::read_to_string(&snap_path)
                .with_context(|| format!("reading {}", snap_path.display()))?;
            let json = Json::parse(&text)
                .map_err(|e| anyhow!("corrupt snapshot {}: {e}", snap_path.display()))?;
            RecoveryModel::from_snapshot_json(&json)
                .with_context(|| format!("parsing {}", snap_path.display()))?
        } else {
            RecoveryModel::default()
        };
        // A leftover tmp snapshot is an unpublished write: discard it.
        let _ = std::fs::remove_file(dir.join(SNAPSHOT_TMP_FILE));

        let wal_path = dir.join(WAL_FILE);
        let mut wal = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&wal_path)
            .with_context(|| format!("opening {}", wal_path.display()))?;
        let mut buf = Vec::new();
        wal.read_to_end(&mut buf)?;
        let (payloads, valid_len, torn_tail) = decode_frames(&buf);
        let mut replayed = 0usize;
        for payload in payloads {
            // A record that frames correctly (checksum valid) but no
            // longer parses is version skew or a bug, not a torn
            // write — recovery refuses to boot rather than silently
            // dropping it and every later record that may depend on
            // it. (A torn *tail* is different: those bytes were never
            // fully written, so truncating them loses nothing acked.)
            let text = std::str::from_utf8(payload).map_err(|_| anyhow!("non-utf8 WAL record"))?;
            let json =
                Json::parse(text).map_err(|e| anyhow!("unparseable WAL record: {e}"))?;
            let (seq, rec) = Record::from_json(&json)?;
            model.apply(seq, &rec);
            replayed += 1;
        }
        if torn_tail {
            wal.set_len(valid_len as u64)?;
        }
        wal.seek(SeekFrom::Start(valid_len as u64))?;
        let recovery = RecoveryReport { objects: model.objects.len(), replayed, torn_tail };
        Ok(ShardLog {
            dir: dir.to_path_buf(),
            sync,
            inner: Mutex::new(LogInner { wal, model, records_since_snapshot: 0 }),
            drain_gate: Mutex::new(()),
            poisoned: std::sync::atomic::AtomicBool::new(false),
            recovery,
            wal_records: AtomicU64::new(0),
            wal_flushes: AtomicU64::new(0),
            wal_errors: AtomicU64::new(0),
            snapshots: AtomicU64::new(0),
            journal_pushes: AtomicU64::new(0),
            journal_cas_retries: AtomicU64::new(0),
            journal_drains: AtomicU64::new(0),
            journal_batch_items_max: AtomicU64::new(0),
            journal_batch_items_total: AtomicU64::new(0),
        })
    }

    /// What recovery found when this log was opened.
    pub fn recovery(&self) -> RecoveryReport {
        self.recovery
    }

    /// True when every mutation appends inline (no group commit).
    pub fn sync_mode(&self) -> bool {
        self.sync
    }

    /// The recovered objects, cloned out for boot-time re-creation.
    pub fn recovered_objects(&self) -> Vec<(String, ObjectState)> {
        let inner = self.inner.lock().unwrap();
        inner.model.objects.iter().map(|(n, o)| (n.clone(), o.clone())).collect()
    }

    /// Append a batch of records: assign sequence numbers, apply them
    /// to the live model, frame and write them, and (in sync mode)
    /// sync to disk. One `write` syscall per batch.
    pub fn append(&self, records: &[Record]) -> Result<()> {
        if records.is_empty() {
            return Ok(());
        }
        let mut inner = self.inner.lock().unwrap();
        self.write_records(&mut inner, records)
    }

    /// The shared append body, under the caller-held inner lock.
    fn write_records(&self, inner: &mut LogInner, records: &[Record]) -> Result<()> {
        if records.is_empty() {
            return Ok(());
        }
        if self.poisoned.load(Ordering::Relaxed) {
            // A previous failed write may have left partial bytes we
            // could not rewind; appending valid frames after garbage
            // would make recovery's torn-tail truncation discard them.
            return Err(anyhow!("WAL poisoned by an unrecoverable write error"));
        }
        let mut buf = Vec::new();
        for rec in records {
            let seq = inner.model.seq + 1;
            inner.model.apply(seq, rec);
            let payload = rec.to_json(seq).to_string();
            encode_frame(payload.as_bytes(), &mut buf);
        }
        let pos = inner.wal.stream_position()?;
        let mut wrote = inner.wal.write_all(&buf);
        if wrote.is_ok() {
            wrote = inner.wal.flush();
        }
        if wrote.is_ok() && self.sync {
            wrote = inner.wal.sync_data();
        }
        if let Err(e) = wrote {
            // Rewind past any partial frame so later (successful)
            // appends never land behind garbage — on crash, recovery
            // would truncate *them* as a torn tail even though they
            // were fsynced and acked. If the rewind itself fails,
            // poison the log: no further appends, errors surface in
            // `wal_errors` and (sync mode) to clients.
            let mut rewound = inner.wal.set_len(pos);
            if rewound.is_ok() {
                rewound = inner.wal.seek(SeekFrom::Start(pos)).map(drop);
            }
            if rewound.is_err() {
                self.poisoned.store(true, Ordering::Relaxed);
            }
            return Err(e.into());
        }
        inner.records_since_snapshot += records.len() as u64;
        self.wal_records.fetch_add(records.len() as u64, Ordering::Relaxed);
        self.wal_flushes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Append drained journal windows, dropping any whose journal was
    /// retired since the drain. The check runs under the log mutex —
    /// the same mutex a delete's `Delete` record goes through after
    /// setting the retired flag — so a flusher that drained an object
    /// just before its delete+re-create cannot append the stale
    /// window *after* the replacement's `Create` record (which would
    /// replay the old object's data into the new one).
    pub(super) fn append_journal_batches(&self, batches: Vec<(&Journal, Vec<Record>)>) {
        let mut inner = self.inner.lock().unwrap();
        let mut records = Vec::new();
        for (journal, recs) in batches {
            if journal.is_retired() {
                continue;
            }
            records.extend(recs);
        }
        if self.write_records(&mut inner, &records).is_err() {
            self.wal_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// [`ShardLog::append`] for hot paths that cannot propagate an IO
    /// error (the mutation has already been applied to the in-memory
    /// object and cannot be withdrawn): failures are counted in
    /// `wal_errors`, visible through `stats`.
    pub fn append_infallible(&self, records: &[Record]) {
        if self.append(records).is_err() {
            self.wal_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Write a compacted snapshot (tmp + fsync + rename + directory
    /// fsync) and truncate the WAL it absorbs. Returns
    /// `(objects, wal records absorbed)`.
    ///
    /// Runs under the inner log mutex end to end, so appends stall
    /// for the duration of one publish (periodic, default every
    /// 60 s; also shutdown/boot/forced). That is the deliberate
    /// price of two hard guarantees a lock-light variant loses: the
    /// WAL truncation is atomic with the publish it reflects (so the
    /// log cannot grow without bound under constant load), and two
    /// racing snapshots cannot rename an older model over a newer
    /// one whose WAL was already truncated.
    pub fn snapshot(&self) -> Result<(usize, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let text = inner.model.to_snapshot_json().to_string();
        let tmp = self.dir.join(SNAPSHOT_TMP_FILE);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(text.as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, self.dir.join(SNAPSHOT_FILE))?;
        // Make the rename itself durable before truncating the WAL:
        // the truncation below reaches disk, so without the directory
        // fsync a crash could surface the *old* snapshot next to an
        // already-empty WAL, losing acked records even in sync mode.
        if let Ok(dir) = File::open(&self.dir) {
            let _ = dir.sync_all();
        }
        inner.wal.set_len(0)?;
        inner.wal.seek(SeekFrom::Start(0))?;
        let _ = inner.wal.sync_data();
        let absorbed = inner.records_since_snapshot;
        inner.records_since_snapshot = 0;
        self.snapshots.fetch_add(1, Ordering::Relaxed);
        Ok((inner.model.objects.len(), absorbed))
    }

    /// Cumulative records appended since open.
    pub fn wal_record_count(&self) -> u64 {
        self.wal_records.load(Ordering::Relaxed)
    }

    /// Cumulative append batches (group commits) since open.
    pub fn wal_flush_count(&self) -> u64 {
        self.wal_flushes.load(Ordering::Relaxed)
    }

    /// Appends that failed with an IO error (durability degraded).
    pub fn wal_error_count(&self) -> u64 {
        self.wal_errors.load(Ordering::Relaxed)
    }

    /// Snapshots written since open.
    pub fn snapshot_count(&self) -> u64 {
        self.snapshots.load(Ordering::Relaxed)
    }

    /// Lock-free journal pushes accepted since open (group-commit
    /// mode buffered records).
    pub fn journal_push_count(&self) -> u64 {
        self.journal_pushes.load(Ordering::Relaxed)
    }

    /// Head-CAS failures burned by journal pushes (contention gauge).
    pub fn journal_cas_retry_count(&self) -> u64 {
        self.journal_cas_retries.load(Ordering::Relaxed)
    }

    /// Non-empty journal windows drained by the flusher since open.
    pub fn journal_drain_count(&self) -> u64 {
        self.journal_drains.load(Ordering::Relaxed)
    }

    /// Largest single drained window, in buffered records.
    pub fn journal_batch_max(&self) -> u64 {
        self.journal_batch_items_max.load(Ordering::Relaxed)
    }

    /// Mean drained-window size, in buffered records per drain.
    pub fn journal_batch_avg(&self) -> f64 {
        let drains = self.journal_drains.load(Ordering::Relaxed);
        if drains == 0 {
            return 0.0;
        }
        self.journal_batch_items_total.load(Ordering::Relaxed) as f64 / drains as f64
    }
}

// ---------------------------------------------------------------------
// Per-object journals
// ---------------------------------------------------------------------

enum JournalState {
    Counter {
        /// Highest acked post-take value not yet flushed…
        hwm: AtomicU64,
        /// …and the value the flusher last emitted, so an idle counter
        /// costs zero records.
        flushed: AtomicU64,
    },
    /// Queue and stack journals: two lock-free claimed stacks, one per
    /// direction. `lifo` selects the record family (`Enqueue`/`Dequeue`
    /// vs `Push`/`Pop`) so replay applies the right removal order.
    Items {
        adds: ClaimStack<Item>,
        removes: ClaimStack<Item>,
        lifo: bool,
    },
}

/// The journaling hook a persisted [`super::ObjectEntry`] carries.
/// In group-commit mode the record hooks are a single `fetch_max`
/// (counters) or a lock-free [`ClaimStack`] push (queues and stacks)
/// — the ack path acquires no mutex or spinlock; the flusher claims
/// each journal's whole window (one CAS) and coalesces it into one
/// record per object. In sync mode each hook appends (and syncs) its
/// record before returning, so a response is never acked before its
/// record is durable.
pub struct Journal {
    log: Arc<ShardLog>,
    name: String,
    /// Set when the object is deleted: a data-plane op still running
    /// on a held `Arc` must not journal into a *re-created* object of
    /// the same name. [`Journal::retire`] also *closes* the claim
    /// stacks, so a push that raced the flag check still fails on the
    /// closed bit — the claim epoch, not lock ordering, is what makes
    /// retire-under-delete airtight.
    retired: std::sync::atomic::AtomicBool,
    /// Jitter seed source for the claim-stack CAS pacing.
    seed: AtomicU64,
    state: JournalState,
}

impl Journal {
    pub fn counter(log: Arc<ShardLog>, name: impl Into<String>) -> Journal {
        Journal {
            log,
            name: name.into(),
            retired: std::sync::atomic::AtomicBool::new(false),
            seed: AtomicU64::new(0),
            state: JournalState::Counter {
                hwm: AtomicU64::new(0),
                flushed: AtomicU64::new(0),
            },
        }
    }

    fn items(log: Arc<ShardLog>, name: String, lifo: bool) -> Journal {
        Journal {
            log,
            name,
            retired: std::sync::atomic::AtomicBool::new(false),
            seed: AtomicU64::new(0),
            state: JournalState::Items {
                adds: ClaimStack::new(),
                removes: ClaimStack::new(),
                lifo,
            },
        }
    }

    pub fn queue(log: Arc<ShardLog>, name: impl Into<String>) -> Journal {
        Journal::items(log, name.into(), false)
    }

    pub fn stack(log: Arc<ShardLog>, name: impl Into<String>) -> Journal {
        Journal::items(log, name.into(), true)
    }

    /// The shard log this journal appends to.
    pub fn log(&self) -> &Arc<ShardLog> {
        &self.log
    }

    /// Stop recording (called when the object is deleted); late ops
    /// on a held handle are applied in memory but no longer journaled.
    /// Closing the claim stacks discards the unflushed window (delete
    /// supersedes it in the WAL) and atomically rejects any push that
    /// already passed the `retired` check.
    pub fn retire(&self) {
        self.retired.store(true, Ordering::Release);
        if let JournalState::Items { adds, removes, .. } = &self.state {
            drop(adds.close());
            drop(removes.close());
        }
    }

    fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    /// The add-direction record for this journal's kind.
    fn add_record(&self, items: Vec<Item>) -> Record {
        match &self.state {
            JournalState::Items { lifo: true, .. } => {
                Record::Push { name: self.name.clone(), items }
            }
            _ => Record::Enqueue { name: self.name.clone(), items },
        }
    }

    /// The remove-direction record for this journal's kind.
    fn remove_record(&self, items: Vec<Item>) -> Record {
        match &self.state {
            JournalState::Items { lifo: true, .. } => {
                Record::Pop { name: self.name.clone(), items }
            }
            _ => Record::Dequeue { name: self.name.clone(), items },
        }
    }

    /// The lock-free buffered-record path: push onto a claim stack and
    /// account for it. A push rejected by the closed bit lost the race
    /// with [`Journal::retire`] — dropping it is exactly the retire
    /// semantics (the delete record supersedes the window).
    fn buffered_push(&self, stack: &ClaimStack<Item>, item: Item) {
        let seed = self.seed.fetch_add(1, Ordering::Relaxed);
        if let Ok(fails) = stack.push(item, seed) {
            self.log.journal_pushes.fetch_add(1, Ordering::Relaxed);
            if fails > 0 {
                self.log.journal_cas_retries.fetch_add(fails as u64, Ordering::Relaxed);
            }
        }
    }

    /// Record the post-take counter value (`start + count`).
    pub fn record_counter(&self, value: u64) {
        if self.is_retired() {
            return;
        }
        let JournalState::Counter { hwm, .. } = &self.state else { return };
        if self.log.sync {
            self.log.append_infallible(&[Record::Counter {
                name: self.name.clone(),
                value,
            }]);
        } else {
            hwm.fetch_max(value, Ordering::Release);
        }
    }

    /// Record one acked enqueue.
    pub fn record_enqueue(&self, item: Item) {
        self.record_add(item);
    }

    /// Record one acked dequeue.
    pub fn record_dequeue(&self, item: Item) {
        self.record_remove(item);
    }

    /// Record one acked push (stack journals).
    pub fn record_push(&self, item: Item) {
        self.record_add(item);
    }

    /// Record one acked pop (stack journals).
    pub fn record_pop(&self, item: Item) {
        self.record_remove(item);
    }

    /// Record a whole coalesced insert batch: in sync mode this is ONE
    /// record (and one fsync'd append) where the per-op path would have
    /// written `items.len()` — the durability win the executor-level
    /// coalescer banks on. In group-commit mode the items join the same
    /// claim-stack window the flusher already merges.
    pub fn record_add_batch(&self, items: Vec<Item>) {
        if items.is_empty() || self.is_retired() {
            return;
        }
        let JournalState::Items { adds, .. } = &self.state else { return };
        if self.log.sync {
            let rec = self.add_record(items);
            self.log.append_infallible(&[rec]);
        } else {
            for item in items {
                self.buffered_push(adds, item);
            }
        }
    }

    /// Record a whole coalesced remove batch (one record in sync mode;
    /// see [`Journal::record_add_batch`]).
    pub fn record_remove_batch(&self, items: Vec<Item>) {
        if items.is_empty() || self.is_retired() {
            return;
        }
        let JournalState::Items { removes, .. } = &self.state else { return };
        if self.log.sync {
            let rec = self.remove_record(items);
            self.log.append_infallible(&[rec]);
        } else {
            for item in items {
                self.buffered_push(removes, item);
            }
        }
    }

    fn record_add(&self, item: Item) {
        if self.is_retired() {
            return;
        }
        let JournalState::Items { adds, .. } = &self.state else { return };
        if self.log.sync {
            let rec = self.add_record(vec![item]);
            self.log.append_infallible(&[rec]);
        } else {
            self.buffered_push(adds, item);
        }
    }

    fn record_remove(&self, item: Item) {
        if self.is_retired() {
            return;
        }
        let JournalState::Items { removes, .. } = &self.state else { return };
        if self.log.sync {
            let rec = self.remove_record(vec![item]);
            self.log.append_infallible(&[rec]);
        } else {
            self.buffered_push(removes, item);
        }
    }

    /// Drain the pending window into records (group-commit mode; a
    /// no-op in sync mode, where nothing buffers). At most one
    /// counter record and one add + one remove record per call,
    /// however many operations the window absorbed.
    pub fn drain_into(&self, out: &mut Vec<Record>) {
        let mut drained_items = 0u64;
        match &self.state {
            JournalState::Counter { hwm, flushed } => {
                let v = hwm.load(Ordering::Acquire);
                if v > flushed.load(Ordering::Relaxed) {
                    flushed.store(v, Ordering::Relaxed);
                    out.push(Record::Counter { name: self.name.clone(), value: v });
                    drained_items = 1;
                }
            }
            JournalState::Items { adds, removes, .. } => {
                // Claim the *remove* window first. Adds are recorded
                // write-ahead (before the item is visible in the
                // object), so any removal claimed here had its add
                // recorded strictly earlier — in an already flushed
                // window or in the add stack we claim next. Claiming
                // adds first would open a window where a fresh add
                // lands in the *next* drain while its removal lands in
                // this one, putting Deq/Pop before Enq/Push in the WAL
                // and resurrecting the item on replay. Each claim is
                // one CAS; between them pushers proceed untouched.
                let d: Vec<Item> = removes.claim().collect();
                let e: Vec<Item> = adds.claim().collect();
                drained_items = (d.len() + e.len()) as u64;
                if !e.is_empty() {
                    out.push(self.add_record(e));
                }
                if !d.is_empty() {
                    out.push(self.remove_record(d));
                }
            }
        }
        if drained_items > 0 {
            self.log.journal_drains.fetch_add(1, Ordering::Relaxed);
            self.log.journal_batch_items_total.fetch_add(drained_items, Ordering::Relaxed);
            self.log.journal_batch_items_max.fetch_max(drained_items, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------
// The flusher
// ---------------------------------------------------------------------

/// Drain every persisted object's journal on `shard` and append the
/// batch (shared by the flusher, the `snapshot` op, and shutdown).
pub(super) fn flush_shard(state: &ServerState, shard: usize) {
    let shard = &state.shards[shard];
    let Some(log) = &shard.log else { return };
    // One drain+append at a time: a racing pair could split a
    // journal's enqueue/dequeue buffers across two appends and
    // invert their WAL order.
    let _gate = log.drain_gate.lock().unwrap();
    let entries = shard.registry.list();
    let mut batches = Vec::new();
    for entry in &entries {
        if let Some(journal) = entry.journal() {
            let mut records = Vec::new();
            journal.drain_into(&mut records);
            if !records.is_empty() {
                batches.push((journal, records));
            }
        }
    }
    // Per-journal batches so the append can drop windows of objects
    // deleted between the drain above and the append's lock.
    log.append_journal_batches(batches);
}

/// Spawn a shard's group-commit flusher: every `fsync_interval_ms` it
/// coalesces the interval's mutations into one WAL append, and every
/// `snapshot_interval_ms` it rewrites the snapshot. Sleeps in short
/// slices so shutdown never waits on a long interval; the *final*
/// flush + snapshot happens in `ServerHandle::shutdown`, not here, so
/// a simulated crash (`ServerHandle::crash`) loses exactly the
/// unflushed window and nothing more.
pub(super) fn spawn_flusher(
    state: Arc<ServerState>,
    shard: usize,
    opts: PersistOpts,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // In sync mode every mutation already appends inline; the
        // only work left is the periodic snapshot, so tick at that
        // cadence instead of spinning on the 0 ms fsync interval.
        let tick_ms = if opts.sync_mode() {
            opts.snapshot_interval_ms.max(1)
        } else {
            opts.fsync_interval_ms.max(1)
        };
        let flush_every = std::time::Duration::from_millis(tick_ms);
        let slice = flush_every.min(std::time::Duration::from_millis(20));
        let snapshot_every = std::time::Duration::from_millis(opts.snapshot_interval_ms);
        let mut since_snapshot = std::time::Duration::ZERO;
        loop {
            let mut slept = std::time::Duration::ZERO;
            while slept < flush_every {
                if state.stopping() {
                    return;
                }
                let chunk = slice.min(flush_every - slept);
                std::thread::sleep(chunk);
                slept += chunk;
            }
            if state.stopping() {
                return;
            }
            if !opts.sync_mode() {
                flush_shard(&state, shard);
            }
            since_snapshot += flush_every;
            if !snapshot_every.is_zero() && since_snapshot >= snapshot_every {
                since_snapshot = std::time::Duration::ZERO;
                if let Some(log) = &state.shards[shard].log {
                    if log.snapshot().is_err() {
                        log.wal_errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::util::prop;

    fn scratch_dir(tag: &str) -> PathBuf {
        crate::util::scratch_dir(&format!("persist-{tag}"))
    }

    fn ctr(name: &str, value: u64) -> Record {
        Record::Counter { name: name.into(), value }
    }

    fn ints(vals: &[u64]) -> Vec<Item> {
        vals.iter().copied().map(Item::Int).collect()
    }

    fn create_rec(name: &str) -> Record {
        Record::Create {
            name: name.into(),
            kind: "counter".into(),
            backend: "elastic:aimd".into(),
            max_width: None,
        }
    }

    #[test]
    fn record_json_roundtrip() {
        let records = vec![
            Record::Create {
                name: "jobs".into(),
                kind: "queue".into(),
                backend: "lcrq+elastic:fixed:2".into(),
                max_width: Some(20),
            },
            create_rec("orders"),
            Record::Delete { name: "jobs".into() },
            ctr("orders", 41),
            Record::Enqueue {
                name: "jobs".into(),
                items: vec![Item::Int(1), Item::Bytes(b"opaque \x00\xFF bytes".to_vec())],
            },
            Record::Dequeue { name: "jobs".into(), items: ints(&[2]) },
            Record::Push {
                name: "undo".into(),
                items: vec![Item::Int(7), Item::Bytes(b"frame".to_vec())],
            },
            Record::Pop { name: "undo".into(), items: ints(&[7]) },
        ];
        for (i, rec) in records.iter().enumerate() {
            let json = rec.to_json(i as u64 + 1);
            let reparsed = Json::parse(&json.to_string()).unwrap();
            let (seq, back) = Record::from_json(&reparsed).unwrap();
            assert_eq!(seq, i as u64 + 1);
            assert_eq!(&back, rec, "record {i}");
        }
    }

    #[test]
    fn frame_codec_roundtrip_and_torn_tail() {
        let mut buf = Vec::new();
        encode_frame(b"alpha", &mut buf);
        encode_frame(b"beta", &mut buf);
        let (payloads, len, torn) = decode_frames(&buf);
        assert_eq!(payloads, vec![b"alpha".as_slice(), b"beta".as_slice()]);
        assert_eq!(len, buf.len());
        assert!(!torn);

        // Truncate mid-frame: the valid prefix survives, the tail is
        // reported torn.
        let mut torn_buf = buf.clone();
        encode_frame(b"gamma-will-be-torn", &mut torn_buf);
        torn_buf.truncate(buf.len() + 7);
        let (payloads, len, torn) = decode_frames(&torn_buf);
        assert_eq!(payloads.len(), 2);
        assert_eq!(len, buf.len());
        assert!(torn);

        // Corrupt a payload byte: its frame (and everything after) is
        // cut off at the checksum.
        let mut corrupt = buf.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0xFF;
        let (payloads, _, torn) = decode_frames(&corrupt);
        assert_eq!(payloads, vec![b"alpha".as_slice()]);
        assert!(torn);

        // Garbage length prefix: nothing decodes, tail reported.
        let garbage = vec![0xFFu8; 32];
        let (payloads, len, torn) = decode_frames(&garbage);
        assert!(payloads.is_empty());
        assert_eq!(len, 0);
        assert!(torn);
    }

    #[test]
    fn model_apply_semantics() {
        let mut m = RecoveryModel::default();
        m.apply(1, &create_rec("c"));
        m.apply(
            2,
            &Record::Create {
                name: "q".into(),
                kind: "queue".into(),
                backend: "lcrq+elastic".into(),
                max_width: None,
            },
        );
        m.apply(3, &ctr("c", 10));
        m.apply(4, &ctr("c", 7)); // stale value: max wins
        m.apply(5, &Record::Enqueue { name: "q".into(), items: ints(&[5, 6, 7]) });
        m.apply(6, &Record::Dequeue { name: "q".into(), items: ints(&[6]) });
        assert_eq!(m.objects["c"].counter, 10);
        assert_eq!(m.objects["q"].items, VecDeque::from(ints(&[5, 7])));
        // Re-create of a live object keeps its state.
        m.apply(7, &create_rec("c"));
        assert_eq!(m.objects["c"].counter, 10);
        // Records at or below the applied seq are skipped (replay
        // idempotence across the snapshot boundary).
        m.apply(5, &Record::Enqueue { name: "q".into(), items: ints(&[5, 6, 7]) });
        assert_eq!(m.objects["q"].items, VecDeque::from(ints(&[5, 7])));
        // Records for unknown objects are ignored, not errors.
        m.apply(8, &ctr("ghost", 3));
        m.apply(9, &Record::Delete { name: "c".into() });
        assert!(!m.objects.contains_key("c"));
        assert_eq!(m.seq, 9);
    }

    #[test]
    fn model_apply_stack_pops_latest_match() {
        let mut m = RecoveryModel::default();
        m.apply(
            1,
            &Record::Create {
                name: "s".into(),
                kind: "stack".into(),
                backend: "stack+elastic".into(),
                max_width: None,
            },
        );
        // Push 5, 6, 5: duplicates must resolve toward the top.
        m.apply(2, &Record::Push { name: "s".into(), items: ints(&[5, 6, 5]) });
        m.apply(3, &Record::Pop { name: "s".into(), items: ints(&[5]) });
        assert_eq!(
            m.objects["s"].items,
            VecDeque::from(ints(&[5, 6])),
            "pop removes the LATEST matching item, not the earliest"
        );
        m.apply(4, &Record::Pop { name: "s".into(), items: ints(&[6, 5]) });
        assert!(m.objects["s"].items.is_empty());
    }

    #[test]
    fn snapshot_json_roundtrip_property() {
        prop::check("snapshot roundtrip", |case| {
            let mut m = RecoveryModel { seq: case.rng.below(1 << 20), ..Default::default() };
            let names = ["a", "b-2", "long_name_3"];
            for name in names {
                if case.rng.below(4) == 0 {
                    continue;
                }
                let queue = case.rng.below(2) == 0;
                let items: VecDeque<Item> = case
                    .vec_of(|r| {
                        if r.below(4) == 0 {
                            Item::Bytes((0..r.below(16)).map(|_| r.below(256) as u8).collect())
                        } else {
                            Item::Int(r.below(1 << 50))
                        }
                    })
                    .into_iter()
                    .collect();
                m.objects.insert(
                    name.to_string(),
                    ObjectState {
                        kind: if queue { "queue" } else { "counter" }.into(),
                        backend: if queue { "lcrq+elastic" } else { "elastic:aimd:d2" }.into(),
                        max_width: if case.rng.below(2) == 0 { None } else { Some(7) },
                        counter: case.rng.below(1 << 50),
                        items: if queue { items } else { VecDeque::new() },
                    },
                );
            }
            let json = m.to_snapshot_json().to_string();
            let back = RecoveryModel::from_snapshot_json(
                &Json::parse(&json).map_err(|e| e.to_string())?,
            )
            .map_err(|e| e.to_string())?;
            prop_assert_eq!(m, back);
            Ok(())
        });
    }

    #[test]
    fn log_append_reopen_recovers() {
        let dir = scratch_dir("reopen");
        {
            let log = ShardLog::open(&dir, true).unwrap();
            assert_eq!(log.recovery().objects, 0);
            log.append(&[create_rec("c"), ctr("c", 5), ctr("c", 12)]).unwrap();
            assert_eq!(log.wal_record_count(), 3);
            // Dropped without snapshot: the WAL alone must recover.
        }
        {
            let log = ShardLog::open(&dir, true).unwrap();
            let report = log.recovery();
            assert_eq!(report.objects, 1);
            assert_eq!(report.replayed, 3);
            assert!(!report.torn_tail);
            let objects = log.recovered_objects();
            assert_eq!(objects[0].0, "c");
            assert_eq!(objects[0].1.counter, 12);
            // Snapshot absorbs the WAL…
            log.append(&[ctr("c", 20)]).unwrap();
            let (objects, absorbed) = log.snapshot().unwrap();
            assert_eq!(objects, 1);
            assert_eq!(absorbed, 1);
        }
        {
            // …and the state survives with an empty WAL.
            let log = ShardLog::open(&dir, true).unwrap();
            let report = log.recovery();
            assert_eq!(report.objects, 1);
            assert_eq!(report.replayed, 0, "snapshot covers everything");
            assert_eq!(log.recovered_objects()[0].1.counter, 20);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_wal_tail_is_truncated_on_reopen() {
        let dir = scratch_dir("torn");
        {
            let log = ShardLog::open(&dir, true).unwrap();
            log.append(&[create_rec("c"), ctr("c", 9)]).unwrap();
        }
        // Simulate a crash mid-append: tack half a frame onto the WAL.
        let wal_path = dir.join(WAL_FILE);
        let valid = std::fs::read(&wal_path).unwrap();
        let mut torn = valid.clone();
        let mut partial = Vec::new();
        encode_frame(br#"{"s":3,"t":"ctr","n":"c","v":99}"#, &mut partial);
        torn.extend_from_slice(&partial[..partial.len() / 2]);
        std::fs::write(&wal_path, &torn).unwrap();
        {
            let log = ShardLog::open(&dir, true).unwrap();
            let report = log.recovery();
            assert!(report.torn_tail, "torn tail must be detected");
            assert_eq!(report.replayed, 2, "valid prefix replays");
            assert_eq!(log.recovered_objects()[0].1.counter, 9, "torn record discarded");
            // The torn bytes are physically gone: new appends start at
            // a clean frame boundary.
            log.append(&[ctr("c", 30)]).unwrap();
        }
        {
            let log = ShardLog::open(&dir, true).unwrap();
            assert!(!log.recovery().torn_tail);
            assert_eq!(log.recovered_objects()[0].1.counter, 30);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crash_between_snapshot_and_truncate_replays_idempotently() {
        let dir = scratch_dir("idem");
        {
            let log = ShardLog::open(&dir, true).unwrap();
            log.append(&[
                Record::Create {
                    name: "q".into(),
                    kind: "queue".into(),
                    backend: "lcrq+elastic".into(),
                    max_width: None,
                },
                Record::Enqueue {
                    name: "q".into(),
                    items: vec![Item::Int(1), Item::Bytes(b"two".to_vec())],
                },
            ])
            .unwrap();
        }
        // Simulate "snapshot published but WAL not truncated": write
        // the snapshot by hand and leave the WAL in place.
        let wal_before = std::fs::read(dir.join(WAL_FILE)).unwrap();
        {
            let log = ShardLog::open(&dir, true).unwrap();
            log.snapshot().unwrap();
        }
        std::fs::write(dir.join(WAL_FILE), &wal_before).unwrap();
        {
            // Replay sees both the snapshot and the old WAL records;
            // the sequence check keeps the enqueue from doubling.
            let log = ShardLog::open(&dir, true).unwrap();
            let items = &log.recovered_objects()[0].1.items;
            assert_eq!(
                *items,
                VecDeque::from(vec![Item::Int(1), Item::Bytes(b"two".to_vec())]),
                "enqueue double-applied"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_group_commit_coalesces() {
        let dir = scratch_dir("journal");
        let log = Arc::new(ShardLog::open(&dir, false).unwrap());
        log.append(&[create_rec("c")]).unwrap();
        let j = Journal::counter(Arc::clone(&log), "c");
        // Many takes, one record.
        for v in [3u64, 9, 6, 12, 11] {
            j.record_counter(v);
        }
        let mut out = Vec::new();
        j.drain_into(&mut out);
        assert_eq!(out, vec![ctr("c", 12)], "window coalesces to the high-water mark");
        // An idle window drains nothing.
        out.clear();
        j.drain_into(&mut out);
        assert!(out.is_empty());

        let q = Journal::queue(Arc::clone(&log), "q");
        q.record_enqueue(Item::Int(1));
        q.record_enqueue(Item::Bytes(b"payload".to_vec()));
        q.record_dequeue(Item::Int(1));
        q.drain_into(&mut out);
        assert_eq!(
            out,
            vec![
                Record::Enqueue {
                    name: "q".into(),
                    items: vec![Item::Int(1), Item::Bytes(b"payload".to_vec())],
                },
                Record::Dequeue { name: "q".into(), items: ints(&[1]) },
            ]
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_sync_mode_appends_inline() {
        let dir = scratch_dir("sync");
        let log = Arc::new(ShardLog::open(&dir, true).unwrap());
        log.append(&[create_rec("c")]).unwrap();
        let j = Journal::counter(Arc::clone(&log), "c");
        j.record_counter(4);
        j.record_counter(9);
        assert_eq!(log.wal_record_count(), 3, "each take appended a record");
        let mut out = Vec::new();
        j.drain_into(&mut out);
        assert!(out.is_empty(), "sync mode buffers nothing");
        drop(j);
        drop(log);
        let log = ShardLog::open(&dir, true).unwrap();
        assert_eq!(log.recovered_objects()[0].1.counter, 9);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_stack_emits_push_pop_records() {
        let dir = scratch_dir("stackj");
        let log = Arc::new(ShardLog::open(&dir, false).unwrap());
        let s = Journal::stack(Arc::clone(&log), "s");
        s.record_push(Item::Int(10));
        s.record_push(Item::Int(11));
        s.record_pop(Item::Int(11));
        let mut out = Vec::new();
        s.drain_into(&mut out);
        assert_eq!(
            out,
            vec![
                Record::Push { name: "s".into(), items: ints(&[10, 11]) },
                Record::Pop { name: "s".into(), items: ints(&[11]) },
            ],
            "stack journals speak psh/pop, adds before removes"
        );
        // Journal metrics observed the window.
        assert_eq!(log.journal_push_count(), 3);
        assert_eq!(log.journal_drain_count(), 1);
        assert_eq!(log.journal_batch_max(), 3);
        assert!((log.journal_batch_avg() - 3.0).abs() < f64::EPSILON);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_retire_discards_window_and_rejects_late_records() {
        // Retire-under-delete without lock ordering: the close on the
        // claim stacks both drops the unflushed window (the Delete
        // record supersedes it) and rejects records that race in after
        // retire, so nothing can replay into a re-created same-name
        // object.
        let dir = scratch_dir("retire");
        let log = Arc::new(ShardLog::open(&dir, false).unwrap());
        let q = Journal::queue(Arc::clone(&log), "q");
        q.record_enqueue(Item::Int(1));
        q.retire();
        q.record_enqueue(Item::Int(2)); // late op on a held handle
        let mut out = Vec::new();
        q.drain_into(&mut out);
        assert!(out.is_empty(), "retired journal must drain nothing");
        // The re-created object gets a fresh journal; the old handle
        // still contributes nothing even if drained again.
        let q2 = Journal::queue(Arc::clone(&log), "q");
        q2.record_enqueue(Item::Int(3));
        q.drain_into(&mut out);
        q2.drain_into(&mut out);
        assert_eq!(out, vec![Record::Enqueue { name: "q".into(), items: ints(&[3]) }]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn journal_concurrent_records_drain_exactly_once() {
        // The tentpole race: producers journal concurrently (no lock)
        // while a drainer claims windows; across all windows every
        // record shows up exactly once and per-producer order holds.
        let dir = scratch_dir("race");
        let log = Arc::new(ShardLog::open(&dir, false).unwrap());
        let j = Arc::new(Journal::queue(Arc::clone(&log), "q"));
        const PRODUCERS: u64 = 4;
        const PER: u64 = 1_000;
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let j = Arc::clone(&j);
                std::thread::spawn(move || {
                    for seq in 0..PER {
                        j.record_enqueue(Item::Int((p << 32) | seq));
                    }
                })
            })
            .collect();
        let mut drained: Vec<Item> = Vec::new();
        while drained.len() < (PRODUCERS * PER) as usize {
            let mut out = Vec::new();
            j.drain_into(&mut out);
            for rec in out {
                let Record::Enqueue { items, .. } = rec else { panic!("unexpected record") };
                drained.extend(items);
            }
            std::thread::yield_now();
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut vals: Vec<u64> = drained
            .iter()
            .map(|i| match i {
                Item::Int(v) => *v,
                _ => unreachable!(),
            })
            .collect();
        // Per-producer order across windows.
        let mut last = vec![None::<u64>; PRODUCERS as usize];
        for v in &vals {
            let (p, seq) = ((v >> 32) as usize, v & 0xFFFF_FFFF);
            if let Some(prev) = last[p] {
                assert!(seq > prev, "producer {p} reordered across drains");
            }
            last[p] = Some(seq);
        }
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len() as u64, PRODUCERS * PER, "lost or duplicated records");
        assert_eq!(log.journal_push_count(), PRODUCERS * PER);
        assert!(log.journal_drain_count() >= 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn persist_opts_modes() {
        assert!(PersistOpts::sync("/tmp/x").sync_mode());
        assert!(!PersistOpts::dir("/tmp/x").sync_mode());
    }
}
