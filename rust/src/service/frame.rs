//! The length-prefixed, checksummed frame codec shared by the binary
//! wire protocol and the on-disk WAL, plus the binary request/response
//! encoding itself.
//!
//! One frame is `len (u32 LE) ‖ fnv1a64(payload) (u64 LE) ‖ payload` —
//! a 12-byte header followed by `len` payload bytes. The WAL has
//! framed its records this way since durability landed; this module
//! hoists that codec out of `persist` so the wire speaks the
//! exact same format, and layers the binary protocol's payload
//! grammar on top:
//!
//! * **Negotiation.** A connection starts in JSON line mode. A client
//!   whose *first bytes* are [`WIRE_MAGIC`] switches the connection to
//!   binary framing; the server answers with one hello frame (a
//!   [`BinResponse::Json`] carrying `{"binary":true,...}`) and every
//!   subsequent byte in either direction is frames. The magic leads
//!   with `0xA6` — not printable ASCII, never the first byte of a JSON
//!   request — so a JSON client can never trip the switch and sees
//!   byte-for-byte the protocol it always had.
//! * **Requests** ([`BinRequest`]): one op per frame, correlated with
//!   responses strictly by order, so clients pipeline freely. The
//!   batch ops — take `k`, enqueue `[items…]`, dequeue `k`, push
//!   `[items…]`, pop `k` — put a whole batch in one frame, which the
//!   funnel executors then feed into single aggregated passes.
//! * **Responses** ([`BinResponse`]): a status byte (`0` ok, else the
//!   [`ErrorCode`] wire byte), an op echo, then op-specific fields.
//! * **Byte-string items** ([`Item`]): queue payloads are either
//!   integers (the historical format) or arbitrary byte strings up to
//!   [`MAX_ITEM_BYTES`]; on the JSON protocol and in WAL records the
//!   byte form travels as a hex string.
//!
//! Decode-time caps make a hostile frame a typed `protocol` error
//! instead of an allocation: payloads over [`MAX_WIRE_FRAME`] are
//! rejected from the length prefix alone, batches over
//! [`MAX_BATCH_ITEMS`] and items over [`MAX_ITEM_BYTES`] are rejected
//! before any item is materialized.

use super::error::ErrorCode;
use super::shard::fnv1a64_bytes;
use crate::util::json::Json;

/// Frame header size: `len (u32 LE) ‖ checksum (u64 LE)`.
pub const FRAME_HEADER: usize = 12;

/// Maximum accepted WAL frame payload length; a length prefix beyond
/// this is treated as a torn/corrupt tail, not an allocation request.
pub const MAX_FRAME_LEN: usize = 1 << 28;

/// Maximum accepted *wire* frame payload length — the binary
/// equivalent of the JSON protocol's `MAX_LINE` request cap (and
/// pinned equal to it by a test).
pub const MAX_WIRE_FRAME: usize = 1 << 20;

/// Most items one batched op may carry (enqueue batch, dequeue
/// count); larger batches are a typed `protocol` error at decode time.
pub const MAX_BATCH_ITEMS: usize = 1 << 16;

/// Largest byte-string queue payload, in bytes.
pub const MAX_ITEM_BYTES: usize = 1 << 16;

/// The 8-byte preamble a binary client sends as its very first bytes.
/// `0xA6` is not printable ASCII (no JSON request starts with it),
/// `b'1'` versions the protocol, and the `\r\n` + NUL tail catches
/// line-ending translation the way PNG's signature does.
pub const WIRE_MAGIC: [u8; 8] = [0xA6, b'A', b'G', b'F', b'1', b'\r', b'\n', 0x00];

/// Frame checksum: FNV-1a over the payload (the same hash the shard
/// router uses, so the whole service has one hash function).
pub fn checksum(payload: &[u8]) -> u64 {
    fnv1a64_bytes(payload)
}

/// Append one length-prefixed, checksummed frame to `out`.
pub fn encode_frame(payload: &[u8], out: &mut Vec<u8>) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a64_bytes(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Decode every complete, checksum-valid frame from the front of
/// `buf`. Returns the payload slices, the byte length of the valid
/// prefix, and whether a torn/corrupt tail was cut off. This is the
/// WAL's batch decoder: it stops at the first bad boundary instead of
/// erroring, because a torn tail is expected after a crash.
pub fn decode_frames(buf: &[u8]) -> (Vec<&[u8]>, usize, bool) {
    let mut payloads = Vec::new();
    let mut pos = 0usize;
    while buf.len() - pos >= FRAME_HEADER {
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().unwrap());
        if len > MAX_FRAME_LEN || buf.len() - pos - FRAME_HEADER < len {
            break; // torn tail: length runs past EOF (or is garbage)
        }
        let payload = &buf[pos + FRAME_HEADER..pos + FRAME_HEADER + len];
        if fnv1a64_bytes(payload) != sum {
            break; // corrupt frame: stop at the last valid boundary
        }
        payloads.push(payload);
        pos += FRAME_HEADER + len;
    }
    let torn = pos != buf.len();
    (payloads, pos, torn)
}

/// One step of incremental wire-side frame decoding.
#[derive(Debug, PartialEq, Eq)]
pub enum WireDecode {
    /// A complete frame: its payload, plus the total bytes (header
    /// included) to drain from the buffer.
    Frame { payload: Vec<u8>, consumed: usize },
    /// Not enough buffered bytes yet — read more.
    Partial,
    /// Framing violation (oversized length prefix or checksum
    /// mismatch). Unlike the WAL's torn tail, a live peer producing
    /// this is broken or hostile; there is no resync point, so the
    /// connection must answer a typed `protocol` error and close.
    Bad(String),
}

/// Try to decode one frame from the front of a connection's read
/// buffer, enforcing the [`MAX_WIRE_FRAME`] cap from the length
/// prefix alone (a hostile header never causes an allocation).
pub fn decode_wire_frame(buf: &[u8]) -> WireDecode {
    if buf.len() < FRAME_HEADER {
        return WireDecode::Partial;
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_WIRE_FRAME {
        return WireDecode::Bad(format!(
            "frame of {len} bytes exceeds the {MAX_WIRE_FRAME}-byte limit"
        ));
    }
    if buf.len() - FRAME_HEADER < len {
        return WireDecode::Partial;
    }
    let sum = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let payload = &buf[FRAME_HEADER..FRAME_HEADER + len];
    if fnv1a64_bytes(payload) != sum {
        return WireDecode::Bad("frame checksum mismatch".to_string());
    }
    WireDecode::Frame { payload: payload.to_vec(), consumed: FRAME_HEADER + len }
}

/// One step of pooled-buffer wire-side frame decoding (the payload
/// lands in a caller-supplied buffer instead of a fresh `Vec`).
#[derive(Debug, PartialEq, Eq)]
pub enum WireDecodeInto {
    /// A complete frame was copied into `out`; drain `consumed` bytes.
    Frame { consumed: usize },
    /// Not enough buffered bytes yet — read more.
    Partial,
    /// Framing violation; same close-the-connection semantics as
    /// [`WireDecode::Bad`].
    Bad(String),
}

/// [`decode_wire_frame`], but the payload is copied into `out`
/// (cleared first). With `out` drawn from a buffer pool the binary
/// read path allocates nothing once the pool is warm.
pub fn decode_wire_frame_into(buf: &[u8], out: &mut Vec<u8>) -> WireDecodeInto {
    if buf.len() < FRAME_HEADER {
        return WireDecodeInto::Partial;
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_WIRE_FRAME {
        return WireDecodeInto::Bad(format!(
            "frame of {len} bytes exceeds the {MAX_WIRE_FRAME}-byte limit"
        ));
    }
    if buf.len() - FRAME_HEADER < len {
        return WireDecodeInto::Partial;
    }
    let sum = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let payload = &buf[FRAME_HEADER..FRAME_HEADER + len];
    if fnv1a64_bytes(payload) != sum {
        return WireDecodeInto::Bad("frame checksum mismatch".to_string());
    }
    out.clear();
    out.extend_from_slice(payload);
    WireDecodeInto::Frame { consumed: FRAME_HEADER + len }
}

// ---------------------------------------------------------------------
// Queue items
// ---------------------------------------------------------------------

/// A queue payload: the historical small-integer form, or an
/// arbitrary byte string (stored behind a per-object item table so
/// the lock-free rings keep trading in small integers). In JSON —
/// wire responses and WAL records alike — an `Int` is a number and
/// `Bytes` is a hex string, which is unambiguous because items were
/// numbers-only before byte payloads existed.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Item {
    /// An integer item (subject to the backend's item-range limits).
    Int(u64),
    /// A byte-string payload, at most [`MAX_ITEM_BYTES`] long.
    Bytes(Vec<u8>),
}

impl Item {
    /// The integer value, if this is an `Int` item.
    pub fn as_int(&self) -> Option<u64> {
        match self {
            Item::Int(v) => Some(*v),
            Item::Bytes(_) => None,
        }
    }

    /// JSON form: `Int` → number, `Bytes` → hex string.
    pub fn to_json(&self) -> Json {
        match self {
            Item::Int(v) => Json::num(*v as f64),
            Item::Bytes(b) => Json::str(to_hex(b)),
        }
    }

    /// Parse the JSON form back ([`Item::to_json`]'s inverse).
    pub fn from_json(v: &Json) -> Option<Item> {
        if let Some(n) = v.as_u64() {
            return Some(Item::Int(n));
        }
        v.as_str().and_then(from_hex).map(Item::Bytes)
    }
}

impl From<u64> for Item {
    fn from(v: u64) -> Item {
        Item::Int(v)
    }
}

/// Lower-case hex encoding of `bytes`.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xF) as u32, 16).unwrap());
    }
    s
}

/// Decode a hex string ([`to_hex`]'s inverse); `None` on odd length
/// or non-hex characters.
pub fn from_hex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let digits = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in digits.chunks(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

// ---------------------------------------------------------------------
// Binary requests
// ---------------------------------------------------------------------

/// Request opcode: the rest of the payload is a JSON document (the
/// control-plane escape hatch — create/delete/list/stats/… reuse the
/// JSON grammar inside one frame).
pub const OP_JSON: u8 = 0x00;
/// Request opcode: take `count` tickets from a counter.
pub const OP_TAKE: u8 = 0x01;
/// Request opcode: read a counter without advancing it.
pub const OP_READ: u8 = 0x02;
/// Request opcode: enqueue a batch of items onto a queue.
pub const OP_ENQUEUE: u8 = 0x03;
/// Request opcode: dequeue up to `count` items from a queue.
pub const OP_DEQUEUE: u8 = 0x04;
/// Request opcode: push a batch of items onto a stack.
pub const OP_PUSH: u8 = 0x05;
/// Request opcode: pop up to `count` items from a stack.
pub const OP_POP: u8 = 0x06;

/// Item tag inside enqueue/dequeue payloads: a `u64 LE` integer.
pub const TAG_INT: u8 = 0;
/// Item tag inside enqueue/dequeue payloads: `u32 LE` length + bytes.
pub const TAG_BYTES: u8 = 1;

/// One decoded binary request (one frame payload).
#[derive(Clone, Debug, PartialEq)]
pub enum BinRequest {
    /// A JSON control-plane document, verbatim.
    Json(String),
    /// `take`: `count` tickets from counter `name`; `priority` uses
    /// the Fetch&AddDirect fast path.
    Take {
        /// Counter object name.
        name: String,
        /// Tickets to take.
        count: u64,
        /// Use the direct (funnel-bypassing) path.
        priority: bool,
    },
    /// `read`: the counter's current value, without advancing it.
    Read {
        /// Counter object name.
        name: String,
    },
    /// `enqueue`: push `items` onto queue `name`, in order, as one
    /// funnel-batched frame.
    Enqueue {
        /// Queue object name.
        name: String,
        /// Items, oldest-enqueued first.
        items: Vec<Item>,
    },
    /// `dequeue`: pop up to `count` items from queue `name`.
    Dequeue {
        /// Queue object name.
        name: String,
        /// Maximum items to pop (the response may carry fewer).
        count: u32,
    },
    /// `push`: push `items` onto stack `name`, in order (the last
    /// item ends up on top).
    Push {
        /// Stack object name.
        name: String,
        /// Items, bottom-most first.
        items: Vec<Item>,
    },
    /// `pop`: pop up to `count` items from stack `name`.
    Pop {
        /// Stack object name.
        name: String,
        /// Maximum items to pop (the response may carry fewer).
        count: u32,
    },
}

impl BinRequest {
    fn op(&self) -> u8 {
        match self {
            BinRequest::Json(_) => OP_JSON,
            BinRequest::Take { .. } => OP_TAKE,
            BinRequest::Read { .. } => OP_READ,
            BinRequest::Enqueue { .. } => OP_ENQUEUE,
            BinRequest::Dequeue { .. } => OP_DEQUEUE,
            BinRequest::Push { .. } => OP_PUSH,
            BinRequest::Pop { .. } => OP_POP,
        }
    }

    /// The object name a data-plane request routes by (`None` for
    /// wrapped JSON documents, which carry their name inside the
    /// document and are routed by the caller).
    pub fn name(&self) -> Option<&str> {
        match self {
            BinRequest::Json(_) => None,
            BinRequest::Take { name, .. }
            | BinRequest::Read { name }
            | BinRequest::Enqueue { name, .. }
            | BinRequest::Dequeue { name, .. }
            | BinRequest::Push { name, .. }
            | BinRequest::Pop { name, .. } => Some(name),
        }
    }
}

fn put_name(name: &str, out: &mut Vec<u8>) {
    debug_assert!(name.len() <= u8::MAX as usize, "names are validated to 64 chars");
    out.push(name.len() as u8);
    out.extend_from_slice(name.as_bytes());
}

fn put_item(item: &Item, out: &mut Vec<u8>) {
    match item {
        Item::Int(v) => {
            out.push(TAG_INT);
            out.extend_from_slice(&v.to_le_bytes());
        }
        Item::Bytes(b) => {
            out.push(TAG_BYTES);
            out.extend_from_slice(&(b.len() as u32).to_le_bytes());
            out.extend_from_slice(b);
        }
    }
}

/// Serialize a request into a frame *payload* (no header; wrap with
/// [`encode_frame`] before writing to a socket).
pub fn encode_request(req: &BinRequest, out: &mut Vec<u8>) {
    out.push(req.op());
    match req {
        BinRequest::Json(doc) => out.extend_from_slice(doc.as_bytes()),
        BinRequest::Take { name, count, priority } => {
            put_name(name, out);
            out.extend_from_slice(&count.to_le_bytes());
            out.push(u8::from(*priority));
        }
        BinRequest::Read { name } => put_name(name, out),
        BinRequest::Enqueue { name, items } => {
            put_name(name, out);
            out.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for item in items {
                put_item(item, out);
            }
        }
        BinRequest::Dequeue { name, count } | BinRequest::Pop { name, count } => {
            put_name(name, out);
            out.extend_from_slice(&count.to_le_bytes());
        }
        BinRequest::Push { name, items } => {
            put_name(name, out);
            out.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for item in items {
                put_item(item, out);
            }
        }
    }
}

/// A bounds-checked cursor over one frame payload; every read that
/// runs past the end becomes a protocol error message, never a panic.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.buf.len() - self.pos < n {
            return Err(format!("truncated frame: {what} needs {n} more byte(s)"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String, String> {
        let len = self.u8("name length")? as usize;
        let raw = self.bytes(len, "object name")?;
        String::from_utf8(raw.to_vec()).map_err(|_| "object name is not UTF-8".to_string())
    }

    fn item(&mut self) -> Result<Item, String> {
        match self.u8("item tag")? {
            TAG_INT => Ok(Item::Int(self.u64("integer item")?)),
            TAG_BYTES => {
                let len = self.u32("byte-item length")? as usize;
                if len > MAX_ITEM_BYTES {
                    return Err(format!(
                        "byte item of {len} bytes exceeds the {MAX_ITEM_BYTES}-byte limit"
                    ));
                }
                Ok(Item::Bytes(self.bytes(len, "byte item")?.to_vec()))
            }
            tag => Err(format!("unknown item tag {tag:#04x}")),
        }
    }

    fn finish(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!("{} trailing byte(s) after the request", self.buf.len() - self.pos));
        }
        Ok(())
    }
}

/// Parse one frame payload into a request, enforcing every batch cap
/// at decode time: take counts above [`super::MAX_TAKE_COUNT`],
/// batches above [`MAX_BATCH_ITEMS`], and items above
/// [`MAX_ITEM_BYTES`] all fail here with a protocol-error message,
/// before any allocation sized by attacker-controlled fields.
pub fn decode_request(payload: &[u8]) -> Result<BinRequest, String> {
    let mut cur = Cursor::new(payload);
    let req = match cur.u8("opcode")? {
        OP_JSON => {
            let rest = &payload[cur.pos..];
            let doc = std::str::from_utf8(rest)
                .map_err(|_| "JSON request is not UTF-8".to_string())?
                .to_string();
            return Ok(BinRequest::Json(doc));
        }
        OP_TAKE => {
            let name = cur.name()?;
            let count = cur.u64("take count")?;
            if count > super::MAX_TAKE_COUNT {
                return Err(format!(
                    "count {count} exceeds the per-request limit {}",
                    super::MAX_TAKE_COUNT
                ));
            }
            let priority = cur.u8("take flags")? & 1 != 0;
            BinRequest::Take { name, count, priority }
        }
        OP_READ => BinRequest::Read { name: cur.name()? },
        OP_ENQUEUE => {
            let name = cur.name()?;
            let n = cur.u32("enqueue batch size")? as usize;
            if n > MAX_BATCH_ITEMS {
                return Err(format!(
                    "enqueue batch of {n} items exceeds the {MAX_BATCH_ITEMS}-item limit"
                ));
            }
            let mut items = Vec::new();
            for _ in 0..n {
                items.push(cur.item()?);
            }
            BinRequest::Enqueue { name, items }
        }
        OP_DEQUEUE => {
            let name = cur.name()?;
            let count = cur.u32("dequeue count")?;
            if count == 0 {
                return Err("dequeue count must be positive".to_string());
            }
            if count as usize > MAX_BATCH_ITEMS {
                return Err(format!(
                    "dequeue count {count} exceeds the {MAX_BATCH_ITEMS}-item limit"
                ));
            }
            BinRequest::Dequeue { name, count }
        }
        OP_PUSH => {
            let name = cur.name()?;
            let n = cur.u32("push batch size")? as usize;
            if n > MAX_BATCH_ITEMS {
                return Err(format!(
                    "push batch of {n} items exceeds the {MAX_BATCH_ITEMS}-item limit"
                ));
            }
            let mut items = Vec::new();
            for _ in 0..n {
                items.push(cur.item()?);
            }
            BinRequest::Push { name, items }
        }
        OP_POP => {
            let name = cur.name()?;
            let count = cur.u32("pop count")?;
            if count == 0 {
                return Err("pop count must be positive".to_string());
            }
            if count as usize > MAX_BATCH_ITEMS {
                return Err(format!("pop count {count} exceeds the {MAX_BATCH_ITEMS}-item limit"));
            }
            BinRequest::Pop { name, count }
        }
        op => return Err(format!("unknown opcode {op:#04x}")),
    };
    cur.finish()?;
    Ok(req)
}

// ---------------------------------------------------------------------
// Binary responses
// ---------------------------------------------------------------------

/// Response status byte for success; any other value is an
/// [`ErrorCode`] wire byte (see [`code_to_byte`]).
pub const STATUS_OK: u8 = 0;

/// [`ErrorCode`] → response status byte (never [`STATUS_OK`]).
pub fn code_to_byte(code: ErrorCode) -> u8 {
    match code {
        ErrorCode::NoSuchObject => 1,
        ErrorCode::WrongKind => 2,
        ErrorCode::AtCapacity => 3,
        ErrorCode::ItemTooLarge => 4,
        ErrorCode::QuotaExceeded => 5,
        ErrorCode::Protocol => 6,
        ErrorCode::Io => 7,
    }
}

/// Response status byte → [`ErrorCode`] ([`code_to_byte`]'s inverse).
pub fn byte_to_code(b: u8) -> Option<ErrorCode> {
    Some(match b {
        1 => ErrorCode::NoSuchObject,
        2 => ErrorCode::WrongKind,
        3 => ErrorCode::AtCapacity,
        4 => ErrorCode::ItemTooLarge,
        5 => ErrorCode::QuotaExceeded,
        6 => ErrorCode::Protocol,
        7 => ErrorCode::Io,
        _ => return None,
    })
}

/// One binary response (one frame payload): `status ‖ op ‖ fields`
/// on success, `status ‖ message` on error. Responses answer requests
/// strictly in order.
#[derive(Clone, Debug, PartialEq)]
pub enum BinResponse {
    /// A typed error: the code that would appear in the JSON
    /// protocol's `"code"` field, plus the human-readable message.
    Err {
        /// The typed error code.
        code: ErrorCode,
        /// The error message (the JSON protocol's `"error"` field).
        msg: String,
    },
    /// A JSON control-plane response document, verbatim.
    Json(String),
    /// `take` succeeded: the start of the dispensed ticket range.
    Start(u64),
    /// `read` succeeded: the counter's current value.
    Value(u64),
    /// `enqueue` succeeded: how many items were enqueued.
    Enqueued(u32),
    /// `dequeue` succeeded: the popped items (fewer than requested —
    /// possibly none — when the queue ran empty).
    Items(Vec<Item>),
    /// `push` succeeded: how many items were pushed.
    Pushed(u32),
    /// `pop` succeeded: the popped items, top-most first (fewer than
    /// requested — possibly none — when the stack ran empty).
    Popped(Vec<Item>),
}

/// Serialize a response into a frame *payload* (no header).
pub fn encode_response(resp: &BinResponse, out: &mut Vec<u8>) {
    match resp {
        BinResponse::Err { code, msg } => {
            out.push(code_to_byte(*code));
            out.extend_from_slice(msg.as_bytes());
        }
        BinResponse::Json(doc) => {
            out.push(STATUS_OK);
            out.push(OP_JSON);
            out.extend_from_slice(doc.as_bytes());
        }
        BinResponse::Start(start) => {
            out.push(STATUS_OK);
            out.push(OP_TAKE);
            out.extend_from_slice(&start.to_le_bytes());
        }
        BinResponse::Value(value) => {
            out.push(STATUS_OK);
            out.push(OP_READ);
            out.extend_from_slice(&value.to_le_bytes());
        }
        BinResponse::Enqueued(n) => {
            out.push(STATUS_OK);
            out.push(OP_ENQUEUE);
            out.extend_from_slice(&n.to_le_bytes());
        }
        BinResponse::Items(items) => {
            out.push(STATUS_OK);
            out.push(OP_DEQUEUE);
            out.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for item in items {
                put_item(item, out);
            }
        }
        BinResponse::Pushed(n) => {
            out.push(STATUS_OK);
            out.push(OP_PUSH);
            out.extend_from_slice(&n.to_le_bytes());
        }
        BinResponse::Popped(items) => {
            out.push(STATUS_OK);
            out.push(OP_POP);
            out.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for item in items {
                put_item(item, out);
            }
        }
    }
}

/// Parse one frame payload into a response ([`encode_response`]'s
/// inverse).
pub fn decode_response(payload: &[u8]) -> Result<BinResponse, String> {
    let mut cur = Cursor::new(payload);
    let status = cur.u8("status")?;
    if status != STATUS_OK {
        let code = byte_to_code(status)
            .ok_or_else(|| format!("unknown response status {status:#04x}"))?;
        let msg = std::str::from_utf8(&payload[cur.pos..])
            .map_err(|_| "error message is not UTF-8".to_string())?
            .to_string();
        return Ok(BinResponse::Err { code, msg });
    }
    let resp = match cur.u8("response op")? {
        OP_JSON => {
            let doc = std::str::from_utf8(&payload[cur.pos..])
                .map_err(|_| "JSON response is not UTF-8".to_string())?
                .to_string();
            return Ok(BinResponse::Json(doc));
        }
        OP_TAKE => BinResponse::Start(cur.u64("take start")?),
        OP_READ => BinResponse::Value(cur.u64("read value")?),
        OP_ENQUEUE => BinResponse::Enqueued(cur.u32("enqueued count")?),
        op @ (OP_DEQUEUE | OP_POP) => {
            let n = cur.u32("item count")? as usize;
            if n > MAX_BATCH_ITEMS {
                return Err(format!(
                    "response batch of {n} items exceeds the {MAX_BATCH_ITEMS}-item limit"
                ));
            }
            let mut items = Vec::new();
            for _ in 0..n {
                items.push(cur.item()?);
            }
            if op == OP_POP {
                BinResponse::Popped(items)
            } else {
                BinResponse::Items(items)
            }
        }
        OP_PUSH => BinResponse::Pushed(cur.u32("pushed count")?),
        op => return Err(format!("unknown response op {op:#04x}")),
    };
    cur.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn wire_frame_cap_matches_the_json_line_cap() {
        // The binary frame limit is the MAX_LINE-equivalent by design;
        // a drift between them would give one protocol a different
        // request ceiling than the other.
        assert_eq!(MAX_WIRE_FRAME, super::super::conn::MAX_LINE);
    }

    #[test]
    fn magic_cannot_prefix_a_json_request() {
        assert!(!WIRE_MAGIC[0].is_ascii(), "first magic byte must be outside ASCII");
        assert_eq!(WIRE_MAGIC.len(), 8);
    }

    #[test]
    fn hex_roundtrip() {
        assert_eq!(to_hex(&[]), "");
        assert_eq!(to_hex(&[0x00, 0xAB, 0xFF]), "00abff");
        assert_eq!(from_hex("00abff"), Some(vec![0x00, 0xAB, 0xFF]));
        assert_eq!(from_hex("0"), None, "odd length");
        assert_eq!(from_hex("zz"), None, "non-hex digits");
        prop::check("hex roundtrip", |case| {
            let bytes: Vec<u8> = case.vec_of(|r| r.below(256) as u8);
            crate::prop_assert_eq!(from_hex(&to_hex(&bytes)), Some(bytes));
            Ok(())
        });
    }

    #[test]
    fn item_json_roundtrip() {
        use crate::util::json::Json;
        let items = vec![Item::Int(0), Item::Int(1 << 50), Item::Bytes(b"hello \xff".to_vec())];
        for item in items {
            let j = item.to_json();
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Item::from_json(&reparsed), Some(item));
        }
        assert_eq!(Item::from_json(&Json::Bool(true)), None);
    }

    fn rand_item(r: &mut Rng) -> Item {
        if r.below(2) == 0 {
            Item::Int(r.below(1 << 50))
        } else {
            Item::Bytes((0..r.below(48)).map(|_| r.below(256) as u8).collect())
        }
    }

    fn rand_request(r: &mut Rng) -> BinRequest {
        match r.below(7) {
            0 => BinRequest::Json("{\"op\":\"list\"}".to_string()),
            1 => BinRequest::Take {
                name: "tickets".into(),
                count: r.below(1 << 30),
                priority: r.below(2) == 0,
            },
            2 => BinRequest::Read { name: "tickets".into() },
            3 => {
                let items = (0..r.below(6)).map(|_| rand_item(r)).collect();
                BinRequest::Enqueue { name: "jobs".into(), items }
            }
            4 => BinRequest::Dequeue { name: "jobs".into(), count: 1 + r.below(64) as u32 },
            5 => {
                let items = (0..r.below(6)).map(|_| rand_item(r)).collect();
                BinRequest::Push { name: "undo".into(), items }
            }
            _ => BinRequest::Pop { name: "undo".into(), count: 1 + r.below(64) as u32 },
        }
    }

    fn rand_response(r: &mut Rng) -> BinResponse {
        match r.below(8) {
            0 => BinResponse::Err {
                code: super::super::error::ErrorCode::NoSuchObject,
                msg: "no object named \"x\"".into(),
            },
            1 => BinResponse::Json("{\"ok\":true}".to_string()),
            2 => BinResponse::Start(r.below(1 << 50)),
            3 => BinResponse::Value(r.below(1 << 50)),
            4 => BinResponse::Enqueued(r.below(1 << 16) as u32),
            5 => BinResponse::Pushed(r.below(1 << 16) as u32),
            6 => BinResponse::Popped((0..r.below(6)).map(|_| rand_item(r)).collect()),
            _ => BinResponse::Items((0..r.below(6)).map(|_| rand_item(r)).collect()),
        }
    }

    #[test]
    fn request_codec_roundtrip_property() {
        prop::check("request roundtrip", |case| {
            let req = rand_request(case.rng);
            let mut payload = Vec::new();
            encode_request(&req, &mut payload);
            let back = decode_request(&payload).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(req, back);
            Ok(())
        });
    }

    #[test]
    fn response_codec_roundtrip_property() {
        prop::check("response roundtrip", |case| {
            let resp = rand_response(case.rng);
            let mut payload = Vec::new();
            encode_response(&resp, &mut payload);
            let back = decode_response(&payload).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(resp, back);
            Ok(())
        });
    }

    #[test]
    fn error_code_bytes_roundtrip_and_never_collide_with_ok() {
        use super::super::error::ErrorCode::*;
        for code in [NoSuchObject, WrongKind, AtCapacity, ItemTooLarge, QuotaExceeded, Protocol, Io]
        {
            let b = code_to_byte(code);
            assert_ne!(b, STATUS_OK, "{code:?} must not encode as OK");
            assert_eq!(byte_to_code(b), Some(code));
        }
        assert_eq!(byte_to_code(0), None);
        assert_eq!(byte_to_code(0xFF), None);
    }

    #[test]
    fn decode_request_enforces_caps() {
        // Oversized take count.
        let mut payload = Vec::new();
        encode_request(
            &BinRequest::Take { name: "t".into(), count: u64::MAX, priority: false },
            &mut payload,
        );
        assert!(decode_request(&payload).unwrap_err().contains("per-request limit"));

        // Oversized declared enqueue batch: rejected from the count
        // field alone, before any item decodes.
        let mut payload = Vec::new();
        payload.push(OP_ENQUEUE);
        payload.push(1);
        payload.push(b'q');
        payload.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(decode_request(&payload).unwrap_err().contains("item limit"));

        // Oversized declared byte item: rejected from its length field.
        let mut payload = Vec::new();
        payload.push(OP_ENQUEUE);
        payload.push(1);
        payload.push(b'q');
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.push(TAG_BYTES);
        payload.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(decode_request(&payload).unwrap_err().contains("byte item"));

        // Zero and oversized dequeue counts.
        let mut payload = Vec::new();
        encode_request(&BinRequest::Dequeue { name: "q".into(), count: 0 }, &mut payload);
        assert!(decode_request(&payload).unwrap_err().contains("positive"));

        // Truncated take: field reads past the payload end.
        let mut payload = Vec::new();
        encode_request(
            &BinRequest::Take { name: "t".into(), count: 3, priority: false },
            &mut payload,
        );
        payload.truncate(payload.len() - 4);
        assert!(decode_request(&payload).unwrap_err().contains("truncated"));

        // Trailing garbage after a well-formed request.
        let mut payload = Vec::new();
        encode_request(&BinRequest::Read { name: "t".into() }, &mut payload);
        payload.push(0xEE);
        assert!(decode_request(&payload).unwrap_err().contains("trailing"));

        // Unknown opcode.
        assert!(decode_request(&[0x7F]).unwrap_err().contains("unknown opcode"));
    }

    #[test]
    fn wire_decoder_handles_partials_corruption_and_oversize() {
        let mut frame = Vec::new();
        encode_frame(b"payload-bytes", &mut frame);

        // Every strict prefix is Partial, never an error.
        for cut in 0..frame.len() {
            assert_eq!(
                decode_wire_frame(&frame[..cut]),
                WireDecode::Partial,
                "prefix of {cut} bytes"
            );
        }
        match decode_wire_frame(&frame) {
            WireDecode::Frame { payload, consumed } => {
                assert_eq!(payload, b"payload-bytes");
                assert_eq!(consumed, frame.len());
            }
            other => panic!("expected a frame, got {other:?}"),
        }

        // An oversized length prefix is rejected without buffering.
        let mut huge = ((MAX_WIRE_FRAME + 1) as u32).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 8]);
        assert!(matches!(decode_wire_frame(&huge), WireDecode::Bad(_)));

        // A flipped payload bit fails the checksum.
        let mut corrupt = frame.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0x01;
        assert!(matches!(decode_wire_frame(&corrupt), WireDecode::Bad(_)));
    }

    #[test]
    fn wire_and_wal_codecs_agree_property() {
        // The tentpole claim: one frame format. Frames produced by the
        // shared encoder decode identically through the WAL's batch
        // decoder and the wire's incremental decoder, and corruption
        // is caught by both.
        prop::check("wire/WAL codec agreement", |case| {
            let payloads: Vec<Vec<u8>> =
                case.vec_of(|r| (0..r.below(40)).map(|_| r.below(256) as u8).collect());
            let mut stream = Vec::new();
            for p in &payloads {
                encode_frame(p, &mut stream);
            }
            // WAL batch decode sees every payload.
            let (wal, consumed, torn) = decode_frames(&stream);
            crate::prop_assert_eq!(wal.len(), payloads.len());
            crate::prop_assert_eq!(consumed, stream.len());
            crate::prop_assert!(!torn, "clean stream reported torn");
            // Incremental wire decode sees the same payloads.
            let mut pos = 0usize;
            let mut wire: Vec<Vec<u8>> = Vec::new();
            loop {
                match decode_wire_frame(&stream[pos..]) {
                    WireDecode::Frame { payload, consumed } => {
                        wire.push(payload);
                        pos += consumed;
                    }
                    WireDecode::Partial => break,
                    WireDecode::Bad(e) => return Err(format!("wire decoder rejected: {e}")),
                }
            }
            crate::prop_assert_eq!(pos, stream.len());
            crate::prop_assert_eq!(wire, payloads);
            // Corrupting any single byte of a non-empty stream makes
            // both decoders stop short of consuming it all.
            if !stream.is_empty() {
                let victim = case.rng.below(stream.len() as u64) as usize;
                let mut bad = stream.clone();
                bad[victim] ^= 0x40;
                let (_, wal_len, wal_torn) = decode_frames(&bad);
                let wire_clean = {
                    let mut pos = 0usize;
                    loop {
                        match decode_wire_frame(&bad[pos..]) {
                            WireDecode::Frame { consumed, .. } => pos += consumed,
                            WireDecode::Partial => break pos == bad.len(),
                            WireDecode::Bad(_) => break false,
                        }
                    }
                };
                crate::prop_assert!(
                    wal_torn || wal_len < bad.len(),
                    "WAL decoder consumed a corrupted stream"
                );
                crate::prop_assert!(!wire_clean, "wire decoder consumed a corrupted stream");
            }
            Ok(())
        });
    }
}
