//! The event-driven connection layer: many sockets, few threads.
//!
//! A small pool of I/O threads polls many non-blocking sockets (via
//! the `sync`-layer [`PollSet`] wrapper over `poll(2)` — no
//! tokio/mio), decodes complete requests into per-connection pending
//! batches, and a fixed set of **funnel executors** — the only tid
//! holders, executor `e` owns tid `1 + e` — drains those batches
//! through the ordinary request handlers. Funnel thread tables stay
//! sized for `workers + FOREIGN_TIDS + 1` tids no matter how many
//! thousands of sockets are open, and the more connections are
//! active, the more ops each executor sweep carries into the funnels
//! per wake-up — exactly the batch-size regime the paper's
//! one-FAA-per-batch amortization wants.
//!
//! **Two wire formats per connection, decided by the first bytes.** A
//! connection that opens with the 8-byte [`frame::WIRE_MAGIC`]
//! preamble switches to the length-prefixed, checksummed binary
//! framing ([`frame::decode_wire_frame`]); the server acks with a
//! `hello` frame advertising `max_frame`, and every later frame maps
//! one request to one response, pipelined in order. Any other first
//! byte pins the JSON line protocol forever — byte-for-byte the
//! pre-binary wire format, since the magic's lead byte `0xA6` can
//! never begin a JSON request. A corrupt or oversized binary frame
//! gets one typed `protocol` error frame and a close: once the length
//! prefix is untrusted the framing cannot resynchronize, unlike a
//! JSON line stream, which self-heals at the next newline.
//!
//! **Accept fan-out.** Thread 0 owns the listener and hands each
//! accepted socket to the least-loaded I/O thread — fewest pending
//! decoded ops, then fewest owned connections — so one firehose
//! client saturates a single poller while quiet connections keep
//! another thread's full attention.
//!
//! Flow control is bounded end to end: at most `max_conns` open
//! connections per shard (excess connects get a clean `at_capacity`
//! error reply, not a silent drop) and at most `max_pending` decoded
//! requests in flight per shard (beyond it the I/O threads stop
//! reading, pushing back through TCP instead of buffering without
//! bound).
//!
//! Shutdown drains: on stop, each I/O thread performs one final read
//! pass (catching requests already in kernel buffers), the executors
//! finish every queued batch, and the I/O threads flush the remaining
//! responses before closing — so a graceful shutdown (or even a
//! `crash()` in tests) never swallows an accepted request. The
//! persist flusher is unaffected: executors journal at the same
//! combining points as the old per-connection handlers, so WAL batch
//! boundaries still track funnel group commits, not socket lifetimes.
//!
//! **Coalescing and fairness.** Each executor sweep drains at most
//! `max_ops_per_sweep` requests per connection (leftovers re-schedule
//! the connection, so a deeply pipelined client shares the executor
//! with its co-scheduled siblings) and hands the whole plan to
//! [`super::coalesce`], which merges same-object same-kind runs into
//! single funnel ops — see that module for the merge rules. The hot
//! path recycles per-request buffers through a per-shard [`BufPool`]
//! (decoded JSON lines and binary frame payloads alike), responses
//! render into per-executor scratch buffers, and [`ConnShared::send`]
//! pushes the backlog and the new bytes with one vectored write.
//! Cross-thread poller wakeups ride a [`SelfPipe`] (pipe2 +
//! O_NONBLOCK), not the old loopback-TCP `WakePing` pair — no port
//! consumption, no dependence on loopback being up.

use std::collections::VecDeque;
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::sync::poll::{PollSet, SelfPipe};
use crate::util::json::Json;

use super::coalesce;
use super::error::ErrorCode;
use super::frame;
use super::ServerState;

/// Connection-layer configuration (per shard).
#[derive(Clone, Debug)]
pub struct ConnOpts {
    /// I/O poller threads per shard. Thread 0 also owns the shard's
    /// listener and fans accepted sockets out by load.
    pub io_threads: usize,
    /// Open-connection ceiling per shard; excess connects are
    /// rejected with an `at_capacity` error reply.
    pub max_conns: usize,
    /// Decoded-but-unexecuted request ceiling per shard; beyond it
    /// the I/O threads stop reading and TCP backpressure reaches the
    /// clients.
    pub max_pending: usize,
    /// Merge same-object same-kind requests drained in one executor
    /// sweep into single funnel ops (see [`super::coalesce`]). On by
    /// default; the off position is the measured baseline of the
    /// `figures coalesce` sweep.
    pub coalesce: bool,
    /// Requests one executor sweep drains from a single connection
    /// before moving on (the fairness cap): a deeply pipelined client
    /// keeps its leftovers queued and re-scheduled rather than
    /// monopolizing the sweep. Clamped to at least 1.
    pub max_ops_per_sweep: usize,
}

impl Default for ConnOpts {
    fn default() -> Self {
        ConnOpts {
            io_threads: 1,
            max_conns: 1024,
            max_pending: 4096,
            coalesce: true,
            max_ops_per_sweep: 128,
        }
    }
}

/// Longest accepted JSON request line (1 MiB). A line beyond it is a
/// protocol error — without a bound one newline-less client would
/// grow a buffer forever. The binary framing enforces the same bound
/// per frame ([`frame::MAX_WIRE_FRAME`]; equality is pinned by a
/// frame test), so switching protocols never changes what a hostile
/// peer can make the server buffer.
pub(crate) const MAX_LINE: usize = 1 << 20;
/// Read chunk size and per-connection read rounds per poll wake-up
/// (bounded so one firehose connection cannot starve its siblings).
const READ_CHUNK: usize = 4096;
const READ_ROUNDS: usize = 16;
/// Connections one executor sweep drains per wake-up; the sweep is
/// the batch whose occupancy `exec_drained_ops / exec_drains`
/// reports.
const SWEEP: usize = 64;
/// Buffers the per-shard pool retains per kind; beyond it a returned
/// buffer is simply dropped (steady state never gets there).
const POOL_LIMIT: usize = 4096;
/// Largest buffer capacity the pool keeps. A one-off huge request
/// (capped by [`MAX_LINE`]/`MAX_WIRE_FRAME`) must not pin a megabyte
/// in the pool forever.
const POOL_MAX_CAP: usize = 64 << 10;

/// A per-shard recycling pool for the hot path's per-request buffers:
/// decoded JSON line `String`s and binary frame payload `Vec<u8>`s.
/// I/O threads draw from it while decoding; executors return buffers
/// after the replies are rendered. Once warm, a steady workload
/// decodes and answers without allocating per request — the
/// `pool_hits` / `pool_misses` gauges in `stats "*"` show the warm-up
/// and the steady state.
pub(super) struct BufPool {
    strings: Mutex<Vec<String>>,
    bufs: Mutex<Vec<Vec<u8>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BufPool {
    fn new() -> Self {
        BufPool {
            strings: Mutex::new(Vec::new()),
            bufs: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn get_string(&self) -> String {
        match self.strings.lock().unwrap().pop() {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                String::new()
            }
        }
    }

    fn put_string(&self, mut s: String) {
        s.clear();
        if s.capacity() == 0 || s.capacity() > POOL_MAX_CAP {
            return;
        }
        let mut pool = self.strings.lock().unwrap();
        if pool.len() < POOL_LIMIT {
            pool.push(s);
        }
    }

    fn get_buf(&self) -> Vec<u8> {
        match self.bufs.lock().unwrap().pop() {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    fn put_buf(&self, mut b: Vec<u8>) {
        b.clear();
        if b.capacity() == 0 || b.capacity() > POOL_MAX_CAP {
            return;
        }
        let mut pool = self.bufs.lock().unwrap();
        if pool.len() < POOL_LIMIT {
            pool.push(b);
        }
    }

    /// Give a finished request's buffer back to the pool.
    fn recycle(&self, req: Request) {
        match req {
            Request::Line(s) => self.put_string(s),
            Request::Frame(b) => self.put_buf(b),
            Request::Overlong(_) | Request::BadFrame(_) => {}
        }
    }
}

/// Per-shard state shared between the I/O threads and the executors.
pub(super) struct EventQueue {
    /// Connections with decoded requests awaiting an executor.
    run: Mutex<VecDeque<Arc<ConnShared>>>,
    cv: Condvar,
    /// Decoded-but-unexecuted requests across the shard (the
    /// backpressure gauge).
    pending_ops: AtomicUsize,
    /// Open connections across the shard's I/O threads.
    conn_count: AtomicUsize,
    /// I/O threads that have not yet finished their shutdown read
    /// pass; executors only exit once it reaches zero with an empty
    /// run queue, so nothing decoded is ever dropped.
    io_live: AtomicUsize,
    next_id: AtomicU64,
    /// Wire traffic counters (both protocols): request bytes read off
    /// sockets, and response/greeting/hello bytes queued for write.
    /// `bytes / ops` is the per-op wire cost the `figures wire` bench
    /// compares across protocols.
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    /// The shard's request-buffer recycling pool.
    pool: BufPool,
}

impl EventQueue {
    pub(super) fn new(io_threads: usize) -> Self {
        EventQueue {
            run: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            pending_ops: AtomicUsize::new(0),
            conn_count: AtomicUsize::new(0),
            io_live: AtomicUsize::new(io_threads.max(1)),
            next_id: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            pool: BufPool::new(),
        }
    }

    /// Decoded requests currently awaiting execution (a gauge, not a
    /// counter — surfaces in per-shard cluster stats).
    pub(super) fn pending_ops(&self) -> usize {
        self.pending_ops.load(Ordering::Relaxed)
    }

    /// Currently open connections on this shard.
    pub(super) fn open_conns(&self) -> usize {
        self.conn_count.load(Ordering::Relaxed)
    }

    /// Total request bytes read off this shard's sockets.
    pub(super) fn bytes_in(&self) -> u64 {
        self.bytes_in.load(Ordering::Relaxed)
    }

    /// Total response bytes queued to this shard's sockets.
    pub(super) fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }

    /// Request buffers served from the recycling pool.
    pub(super) fn pool_hits(&self) -> u64 {
        self.pool.hits.load(Ordering::Relaxed)
    }

    /// Request buffers freshly allocated (pool empty — warm-up, or a
    /// burst beyond the pool's high-water mark).
    pub(super) fn pool_misses(&self) -> u64 {
        self.pool.misses.load(Ordering::Relaxed)
    }
}

/// Per-I/O-thread load cell, read by the acceptor's fan-out and
/// updated on the owning thread's hot paths with relaxed atomics.
pub(super) struct IoLoad {
    /// Connections owned by (or already routed to) the thread.
    conns: AtomicUsize,
    /// Decoded requests from the thread's connections still awaiting
    /// an executor.
    pending: AtomicUsize,
}

impl IoLoad {
    fn new() -> Self {
        IoLoad { conns: AtomicUsize::new(0), pending: AtomicUsize::new(0) }
    }
}

/// The fan-out decision: the thread with the fewest pending decoded
/// ops, connection count breaking ties (then the lowest index, which
/// keeps a single-threaded shard on thread 0). Pending ops lead
/// because they measure *work in flight* — a thread may own many
/// quiet connections and still be the right home for the next socket.
fn least_loaded(loads: &[Arc<IoLoad>]) -> usize {
    let mut pick = 0usize;
    let mut best = (usize::MAX, usize::MAX);
    for (i, load) in loads.iter().enumerate() {
        let key = (load.pending.load(Ordering::Relaxed), load.conns.load(Ordering::Relaxed));
        if key < best {
            best = key;
            pick = i;
        }
    }
    pick
}

/// The half of a connection both sides touch: executors append
/// responses and re-schedule; I/O threads enqueue decoded requests
/// and flush output. The `scheduled` flag guarantees a connection
/// sits in the run queue at most once, which also serializes
/// execution per connection — responses keep request order.
struct ConnShared {
    writer: TcpStream,
    wake: Arc<SelfPipe>,
    /// The owning I/O thread's load cell, so executors can retire
    /// this connection's share of the fan-out pending count.
    io_load: Arc<IoLoad>,
    /// Bytes written by executors but not yet accepted by the socket.
    out: Mutex<Vec<u8>>,
    /// Decoded requests awaiting execution, in arrival order.
    requests: Mutex<VecDeque<Request>>,
    scheduled: AtomicBool,
    /// Peer finished sending (EOF/read error); drain, then reap.
    read_closed: AtomicBool,
    /// Write side failed; nothing further can be delivered.
    dead: AtomicBool,
}

/// One decoded unit of a connection's request stream. Keeping
/// malformed lines *in the queue* — instead of replying to them from
/// the I/O thread — preserves the pipelining contract: every request
/// gets exactly one reply, in the order the requests were sent, even
/// when some of them are garbage.
pub(super) enum Request {
    /// A complete JSON request line, ready for `handle_request`.
    Line(String),
    /// A line that exceeded [`MAX_LINE`] (bytes seen so far, for the
    /// error reply). The line is dropped through its newline —
    /// immediately if it arrived terminated, via the connection's
    /// discard mode otherwise — so framing stays intact and the
    /// connection lives on.
    Overlong(usize),
    /// A complete binary frame payload, ready for `handle_binary`.
    Frame(Vec<u8>),
    /// A binary framing violation (bad checksum, oversized length
    /// prefix, bad negotiation magic). Queued *in position* so every
    /// pipelined request before it still gets its reply; the reader
    /// has already stopped, so the typed error frame is the
    /// connection's last word.
    BadFrame(String),
}

impl ConnShared {
    /// Queue `bytes` for this connection and push them — backlog
    /// first, then the new bytes, in one vectored write per syscall —
    /// as far as the socket will take them right now. In the common
    /// case (no backlog, socket writable) the reply bytes go from the
    /// executor's scratch buffer straight to the kernel without ever
    /// being copied into `out`; only the unaccepted remainder is
    /// buffered, waiting for POLLOUT (the wake tells the owning I/O
    /// thread to start watching).
    fn send(&self, bytes: &[u8]) {
        if self.dead.load(Ordering::Acquire) {
            return;
        }
        let mut out = self.out.lock().unwrap();
        let mut old = 0usize; // consumed from the backlog
        let mut new = 0usize; // consumed from `bytes`
        loop {
            let res = if out.len() > old {
                let slices = [IoSlice::new(&out[old..]), IoSlice::new(&bytes[new..])];
                (&self.writer).write_vectored(&slices)
            } else if bytes.len() > new {
                (&self.writer).write(&bytes[new..])
            } else {
                break;
            };
            match res {
                Ok(0) => {
                    self.dead.store(true, Ordering::Release);
                    break;
                }
                Ok(n) => {
                    let from_old = n.min(out.len() - old);
                    old += from_old;
                    new += n - from_old;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead.store(true, Ordering::Release);
                    break;
                }
            }
        }
        if self.dead.load(Ordering::Acquire) {
            out.clear();
            return;
        }
        out.drain(..old);
        out.extend_from_slice(&bytes[new..]);
        let wake = !out.is_empty();
        drop(out);
        if wake {
            self.wake.wake();
        }
    }

    /// Write as much buffered output as the non-blocking socket
    /// accepts. Called by executors (opportunistically, right after a
    /// batch) and by I/O threads (on POLLOUT); the `out` lock makes
    /// the writes atomic with respect to each other.
    fn flush(&self) {
        let mut out = self.out.lock().unwrap();
        let mut written = 0;
        while written < out.len() {
            match (&self.writer).write(&out[written..]) {
                Ok(0) => {
                    self.dead.store(true, Ordering::Release);
                    break;
                }
                Ok(n) => written += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead.store(true, Ordering::Release);
                    break;
                }
            }
        }
        if self.dead.load(Ordering::Acquire) {
            out.clear();
        } else {
            out.drain(..written);
        }
    }

    /// Fully drained and idle (or beyond saving)?
    fn quiesced(&self) -> bool {
        self.dead.load(Ordering::Acquire)
            || (!self.scheduled.load(Ordering::Acquire)
                && self.requests.lock().unwrap().is_empty()
                && self.out.lock().unwrap().is_empty())
    }
}

/// Put a connection on the run queue unless it is already there.
fn schedule(evq: &EventQueue, conn: &Arc<ConnShared>) {
    if !conn.scheduled.swap(true, Ordering::AcqRel) {
        evq.run.lock().unwrap().push_back(Arc::clone(conn));
        evq.cv.notify_one();
    }
}

/// Spawn one shard's event core: `io_threads` pollers (thread 0 owns
/// the listener) plus `workers` funnel executors. All threads exit on
/// the server stop flag after the drain protocol described in the
/// module docs.
pub(super) fn spawn_event_core(
    state: &Arc<ServerState>,
    shard: usize,
    listener: TcpListener,
    opts: &ConnOpts,
    workers: usize,
) -> std::io::Result<Vec<std::thread::JoinHandle<()>>> {
    let evq = Arc::clone(
        state.shards[shard].evq.as_ref().expect("event core needs the shard's EventQueue"),
    );
    let io_n = opts.io_threads.max(1);
    let mut wakes: Vec<Arc<SelfPipe>> = Vec::with_capacity(io_n);
    let mut inboxes: Vec<Inbox> = Vec::with_capacity(io_n);
    let mut loads: Vec<Arc<IoLoad>> = Vec::with_capacity(io_n);
    for _ in 0..io_n {
        wakes.push(Arc::new(SelfPipe::new()?));
        inboxes.push(Arc::new(Mutex::new(Vec::new())));
        loads.push(Arc::new(IoLoad::new()));
    }
    let mut threads = Vec::with_capacity(io_n + workers);
    let mut listener = Some(listener);
    for t in 0..io_n {
        let io = IoThread {
            state: Arc::clone(state),
            shard,
            evq: Arc::clone(&evq),
            listener: if t == 0 { listener.take() } else { None },
            wake: Arc::clone(&wakes[t]),
            inbox: Arc::clone(&inboxes[t]),
            inboxes: inboxes.clone(),
            wakes: wakes.clone(),
            load: Arc::clone(&loads[t]),
            loads: loads.clone(),
            opts: opts.clone(),
            conns: Vec::new(),
        };
        threads.push(std::thread::spawn(move || io.run()));
    }
    for e in 0..workers.max(1) {
        let state = Arc::clone(state);
        let evq = Arc::clone(&evq);
        let opts = opts.clone();
        // Executors are the shard's only funnel tid holders:
        // executor `e` owns tid `1 + e` outright (tid 0 stays
        // reserved for in-process callers, the foreign pool above
        // `workers` still serves forwarded ops).
        let tid = 1 + e;
        threads
            .push(std::thread::spawn(move || executor_loop(&state, shard, tid, &evq, &opts)));
    }
    Ok(threads)
}

type Inbox = Arc<Mutex<Vec<(u64, TcpStream)>>>;

/// The protocol a connection speaks, decided once by its first bytes
/// and never renegotiated.
enum Wire {
    /// No bytes seen yet (or only a proper prefix of the magic).
    Undecided,
    /// Newline-framed JSON — any first byte other than the magic's.
    Json,
    /// Length-prefixed checksummed frames, after a full magic match.
    Binary,
}

/// A connection owned by one I/O thread.
struct IoConn {
    stream: TcpStream,
    /// Bytes read but not yet decoded into a full line or frame.
    buf: Vec<u8>,
    /// Mid-discard of an overlong JSON line: swallow bytes
    /// (unbuffered) until the next newline restores framing. The
    /// error reply was already queued when the cap tripped.
    discarding: bool,
    wire: Wire,
    shared: Arc<ConnShared>,
}

struct IoThread {
    state: Arc<ServerState>,
    shard: usize,
    evq: Arc<EventQueue>,
    /// Thread 0 owns the shard listener; the rest only poll conns.
    listener: Option<TcpListener>,
    /// This thread's self-pipe: the read end sits in the poll set,
    /// and executors (or the acceptor) write a byte to interrupt the
    /// `poll(2)` sleep.
    wake: Arc<SelfPipe>,
    inbox: Inbox,
    inboxes: Vec<Inbox>,
    wakes: Vec<Arc<SelfPipe>>,
    /// This thread's load cell (same Arc as `loads[self index]`).
    load: Arc<IoLoad>,
    /// Every thread's load cell, for the acceptor's fan-out pick.
    loads: Vec<Arc<IoLoad>>,
    opts: ConnOpts,
    conns: Vec<IoConn>,
}

impl IoThread {
    fn run(mut self) {
        let mut set = PollSet::new();
        while !self.state.stopping() {
            set.clear();
            let listener_slot = self.listener.as_ref().map(|l| set.push(l, true, false));
            let wake_slot = set.push(self.wake.as_ref(), true, false);
            // Backpressure: past `max_pending` decoded requests, stop
            // reading everywhere on this shard; TCP receive windows
            // fill and the clients feel it. Output still flushes, so
            // the executors drain the backlog and reads resume.
            let stalled = self.evq.pending_ops() >= self.opts.max_pending.max(1);
            if stalled {
                self.state.shards[self.shard].metrics.incr("backpressure_stalls");
            }
            let mut conn_slots = Vec::with_capacity(self.conns.len());
            for c in &self.conns {
                let read = !stalled
                    && !c.shared.read_closed.load(Ordering::Acquire)
                    && !c.shared.dead.load(Ordering::Acquire);
                let write = !c.shared.out.lock().unwrap().is_empty();
                conn_slots.push(set.push(&c.stream, read, write));
            }
            let _ = set.poll(50);
            if self.state.stopping() {
                break;
            }
            if set.readable(wake_slot) {
                self.wake.drain();
            }
            for (i, slot) in conn_slots.into_iter().enumerate() {
                if set.readable(slot) {
                    self.read_conn(i);
                }
                if set.writable(slot) {
                    self.conns[i].shared.flush();
                }
            }
            if let Some(slot) = listener_slot {
                if set.readable(slot) {
                    self.accept_round();
                }
            }
            self.adopt_inbox();
            self.reap();
        }
        self.drain_and_close();
    }

    /// Accept everything the listener has ready, admitting up to
    /// `max_conns` per shard and rejecting the rest with a clean
    /// `at_capacity` reply (never a silent drop).
    fn accept_round(&mut self) {
        let Some(listener) = &self.listener else { return };
        loop {
            let conn = match listener.accept() {
                Ok((conn, _)) => conn,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock or transient: next poll retries
            };
            let metrics = &self.state.shards[self.shard].metrics;
            metrics.incr("connections");
            if self.evq.open_conns() >= self.opts.max_conns.max(1) {
                metrics.incr("rejected");
                reject_at_capacity(&self.state, self.shard, conn, self.opts.max_conns.max(1));
                continue;
            }
            self.evq.conn_count.fetch_add(1, Ordering::AcqRel);
            metrics.incr("conn_open");
            let id = self.evq.next_id.fetch_add(1, Ordering::Relaxed);
            // Fan out by load, and count the routed socket against
            // its new owner immediately so a burst accepted in one
            // round spreads instead of piling onto a single pick.
            let t = least_loaded(&self.loads);
            self.loads[t].conns.fetch_add(1, Ordering::Relaxed);
            self.inboxes[t].lock().unwrap().push((id, conn));
            if t != 0 {
                self.wakes[t].wake();
            }
        }
    }

    /// Take ownership of connections the acceptor routed here.
    fn adopt_inbox(&mut self) {
        let adopted: Vec<(u64, TcpStream)> = self.inbox.lock().unwrap().drain(..).collect();
        for (_, stream) in adopted {
            if stream.set_nonblocking(true).is_err() {
                self.evq.conn_count.fetch_sub(1, Ordering::AcqRel);
                self.load.conns.fetch_sub(1, Ordering::Relaxed);
                self.state.shards[self.shard].metrics.incr("conn_closed");
                continue;
            }
            stream.set_nodelay(true).ok();
            let writer = match stream.try_clone() {
                Ok(w) => w,
                Err(_) => {
                    self.evq.conn_count.fetch_sub(1, Ordering::AcqRel);
                    self.load.conns.fetch_sub(1, Ordering::Relaxed);
                    self.state.shards[self.shard].metrics.incr("conn_closed");
                    continue;
                }
            };
            let shared = Arc::new(ConnShared {
                writer,
                wake: Arc::clone(&self.wake),
                io_load: Arc::clone(&self.load),
                out: Mutex::new(Vec::new()),
                requests: Mutex::new(VecDeque::new()),
                scheduled: AtomicBool::new(false),
                read_closed: AtomicBool::new(false),
                dead: AtomicBool::new(false),
            });
            // Sharded servers greet on connect — the one JSON line a
            // binary-negotiating client must skip before its hello
            // frame; single-shard servers stay silent.
            if self.state.shards.len() > 1 {
                let mut greeting =
                    self.state.shardmap_json(self.shard, true).to_string().into_bytes();
                greeting.push(b'\n');
                self.evq.bytes_out.fetch_add(greeting.len() as u64, Ordering::Relaxed);
                shared.send(&greeting);
            }
            self.conns.push(IoConn {
                stream,
                buf: Vec::new(),
                discarding: false,
                wire: Wire::Undecided,
                shared,
            });
        }
    }

    /// Non-blocking read rounds for one connection: pull what the
    /// kernel has, decode complete requests — JSON lines or binary
    /// frames, per the connection's negotiated wire — into the
    /// request queue, and schedule the connection for an executor.
    fn read_conn(&mut self, i: usize) {
        let c = &mut self.conns[i];
        if c.shared.read_closed.load(Ordering::Acquire) || c.shared.dead.load(Ordering::Acquire)
        {
            return;
        }
        let mut chunk = [0u8; READ_CHUNK];
        let mut got = 0usize;
        for _ in 0..READ_ROUNDS {
            match (&c.stream).read(&mut chunk) {
                Ok(0) => {
                    c.shared.read_closed.store(true, Ordering::Release);
                    break;
                }
                Ok(n) => {
                    c.buf.extend_from_slice(&chunk[..n]);
                    got += n;
                    if n < READ_CHUNK {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    c.shared.read_closed.store(true, Ordering::Release);
                    break;
                }
            }
        }
        if got > 0 {
            self.evq.bytes_in.fetch_add(got as u64, Ordering::Relaxed);
        }
        let mut pushed = 0usize;
        loop {
            match c.wire {
                Wire::Undecided => {
                    let Some(&first) = c.buf.first() else { break };
                    if first != frame::WIRE_MAGIC[0] {
                        // Not the magic's lead byte: this connection
                        // speaks JSON lines forever. `0xA6` can never
                        // begin a JSON request, so old clients are
                        // never misdetected.
                        c.wire = Wire::Json;
                        continue;
                    }
                    if c.buf.len() < frame::WIRE_MAGIC.len()
                        && frame::WIRE_MAGIC.starts_with(&c.buf)
                    {
                        break; // a proper magic prefix: wait for the rest
                    }
                    if c.buf.starts_with(&frame::WIRE_MAGIC) {
                        c.buf.drain(..frame::WIRE_MAGIC.len());
                        c.wire = Wire::Binary;
                        self.state.shards[self.shard].metrics.incr("conn_binary");
                        // Ack the switch with a hello frame so the
                        // client can pipeline knowing the frame cap.
                        let hello = Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("binary", Json::Bool(true)),
                            ("max_frame", Json::num(frame::MAX_WIRE_FRAME as f64)),
                        ]);
                        let mut payload = Vec::new();
                        frame::encode_response(
                            &frame::BinResponse::Json(hello.to_string()),
                            &mut payload,
                        );
                        let mut ack = Vec::new();
                        frame::encode_frame(&payload, &mut ack);
                        self.evq.bytes_out.fetch_add(ack.len() as u64, Ordering::Relaxed);
                        c.shared.send(&ack);
                        continue;
                    }
                    // Lead byte matched the magic but the rest
                    // diverged: a broken binary client, not a JSON
                    // one. One typed error, then close.
                    let seen = &c.buf[..c.buf.len().min(frame::WIRE_MAGIC.len())];
                    c.shared
                        .requests
                        .lock()
                        .unwrap()
                        .push_back(Request::BadFrame(format!(
                            "bad negotiation magic {seen:02x?}"
                        )));
                    pushed += 1;
                    c.buf.clear();
                    c.shared.read_closed.store(true, Ordering::Release);
                    break;
                }
                Wire::Json => {
                    if c.discarding {
                        // The head of the buffer is the tail of an
                        // overlong line (already answered); swallow
                        // through its newline.
                        match c.buf.iter().position(|&b| b == b'\n') {
                            Some(pos) => {
                                c.buf.drain(..=pos);
                                c.discarding = false;
                                continue;
                            }
                            None => {
                                c.buf.clear();
                                break;
                            }
                        }
                    }
                    let Some(pos) = c.buf.iter().position(|&b| b == b'\n') else {
                        if c.buf.len() > MAX_LINE {
                            // Cap tripped mid-line: queue the error
                            // *in position* and discard until the
                            // next newline — requests pipelined
                            // behind the oversized line still get
                            // answered, in order.
                            c.shared
                                .requests
                                .lock()
                                .unwrap()
                                .push_back(Request::Overlong(c.buf.len()));
                            pushed += 1;
                            c.buf.clear();
                            c.discarding = true;
                        }
                        break;
                    };
                    if pos + 1 > MAX_LINE {
                        // Oversized but newline-terminated within
                        // this read: same in-position error, framing
                        // already intact.
                        c.buf.drain(..=pos);
                        c.shared
                            .requests
                            .lock()
                            .unwrap()
                            .push_back(Request::Overlong(pos));
                        pushed += 1;
                        continue;
                    }
                    // Decode into a pooled String: the fast path is a
                    // UTF-8 check plus a copy into recycled capacity;
                    // only invalid UTF-8 (which the JSON parser would
                    // reject anyway) takes the lossy allocation.
                    let mut text = self.evq.pool.get_string();
                    match std::str::from_utf8(&c.buf[..pos]) {
                        Ok(s) => text.push_str(s),
                        Err(_) => text.push_str(&String::from_utf8_lossy(&c.buf[..pos])),
                    }
                    c.buf.drain(..=pos);
                    if text.trim().is_empty() {
                        self.evq.pool.put_string(text);
                        continue;
                    }
                    c.shared.requests.lock().unwrap().push_back(Request::Line(text));
                    pushed += 1;
                }
                Wire::Binary => {
                    let mut payload = self.evq.pool.get_buf();
                    match frame::decode_wire_frame_into(&c.buf, &mut payload) {
                        frame::WireDecodeInto::Frame { consumed } => {
                            c.buf.drain(..consumed);
                            c.shared
                                .requests
                                .lock()
                                .unwrap()
                                .push_back(Request::Frame(payload));
                            pushed += 1;
                        }
                        frame::WireDecodeInto::Partial => {
                            self.evq.pool.put_buf(payload);
                            break;
                        }
                        frame::WireDecodeInto::Bad(msg) => {
                            // Corrupt length prefix or checksum: the
                            // stream cannot be re-framed. Stop reading;
                            // the queued error is the final reply.
                            self.evq.pool.put_buf(payload);
                            c.shared
                                .requests
                                .lock()
                                .unwrap()
                                .push_back(Request::BadFrame(msg));
                            pushed += 1;
                            c.buf.clear();
                            c.shared.read_closed.store(true, Ordering::Release);
                            break;
                        }
                    }
                }
            }
        }
        if pushed > 0 {
            self.evq.pending_ops.fetch_add(pushed, Ordering::AcqRel);
            self.load.pending.fetch_add(pushed, Ordering::Relaxed);
            schedule(&self.evq, &c.shared);
        }
    }

    /// Drop connections that are gone and fully drained.
    fn reap(&mut self) {
        let evq = &self.evq;
        let metrics = &self.state.shards[self.shard].metrics;
        self.conns.retain(|c| {
            let gone = c.shared.dead.load(Ordering::Acquire)
                || (c.shared.read_closed.load(Ordering::Acquire) && c.shared.quiesced());
            if gone {
                evq.conn_count.fetch_sub(1, Ordering::AcqRel);
                c.shared.io_load.conns.fetch_sub(1, Ordering::Relaxed);
                metrics.incr("conn_closed");
            }
            !gone
        });
    }

    /// Shutdown: one final read pass catches requests already sitting
    /// in kernel buffers, then executors are released (`io_live`),
    /// then responses flush until every connection is quiet (bounded
    /// by a deadline so a stuck peer cannot hang `shutdown()`).
    fn drain_and_close(mut self) {
        for i in 0..self.conns.len() {
            self.read_conn(i);
        }
        self.evq.io_live.fetch_sub(1, Ordering::AcqRel);
        self.evq.cv.notify_all();
        let deadline = Instant::now() + Duration::from_secs(3);
        while Instant::now() < deadline {
            for c in &self.conns {
                c.shared.flush();
            }
            if self.conns.iter().all(|c| c.shared.quiesced()) {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// One funnel executor: sweep up to [`SWEEP`] scheduled connections
/// per wake-up and run their queued requests on this executor's tid.
/// The sweep is the drain the occupancy metrics describe — under many
/// active connections each wake-up carries many ops into the funnels.
///
/// Each sweep gathers at most `max_ops_per_sweep` requests per
/// connection into one flat plan (leftovers re-queue via the re-arm
/// below, so a flooding pipeline cannot starve its neighbours), hands
/// the plan to [`coalesce::execute_sweep`] for cross-connection
/// merging, then renders and flushes each connection's contiguous
/// reply span. All scratch — plan, outcomes, reply buffers — lives in
/// one per-executor [`coalesce::Scratch`] reused across sweeps, and
/// drained request buffers return to the shard's [`BufPool`].
fn executor_loop(
    state: &Arc<ServerState>,
    shard: usize,
    tid: usize,
    evq: &EventQueue,
    opts: &ConnOpts,
) {
    let cap = opts.max_ops_per_sweep.max(1);
    let mut scratch = coalesce::Scratch::new();
    let mut spans: Vec<(Arc<ConnShared>, usize, usize)> = Vec::new();
    loop {
        let mut batch: Vec<Arc<ConnShared>> = Vec::new();
        {
            let mut q = evq.run.lock().unwrap();
            loop {
                while batch.len() < SWEEP {
                    match q.pop_front() {
                        Some(c) => batch.push(c),
                        None => break,
                    }
                }
                if !batch.is_empty() {
                    break;
                }
                if state.stopping() && evq.io_live.load(Ordering::Acquire) == 0 {
                    return;
                }
                let (guard, _) = evq.cv.wait_timeout(q, Duration::from_millis(25)).unwrap();
                q = guard;
            }
        }
        let metrics = &state.shards[shard].metrics;
        scratch.begin();
        spans.clear();
        let mut truncated = 0u64;
        for conn in batch {
            let start = scratch.len();
            {
                let mut q = conn.requests.lock().unwrap();
                let take = q.len().min(cap);
                if take < q.len() {
                    truncated += 1;
                }
                for _ in 0..take {
                    scratch.push(q.pop_front().unwrap());
                }
            }
            spans.push((conn, start, scratch.len()));
        }
        if truncated > 0 {
            metrics.add("sweep_truncated", truncated);
        }
        let ops = scratch.len();
        if ops > 0 {
            // Every queued request — valid, failing, or malformed —
            // produces exactly one outcome, in arrival order; a bad
            // op in the middle of a pipelined batch never shifts or
            // aborts the replies behind it.
            coalesce::execute_sweep(state, shard, tid, opts.coalesce, &mut scratch);
        }
        for (conn, start, end) in spans.drain(..) {
            let n = end - start;
            if n > 0 {
                let bytes = scratch.render_span(start, end);
                evq.pending_ops.fetch_sub(n, Ordering::AcqRel);
                conn.io_load.pending.fetch_sub(n, Ordering::Relaxed);
                evq.bytes_out.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                conn.send(bytes);
            }
            // Re-arm: clear the scheduled flag, then re-check — a
            // producer that pushed between the drain and the clear
            // skipped its own schedule (the flag was still set), so
            // the re-check re-queues; the swap keeps it single-entry.
            conn.scheduled.store(false, Ordering::Release);
            let more = !conn.requests.lock().unwrap().is_empty();
            if more && !conn.scheduled.swap(true, Ordering::AcqRel) {
                evq.run.lock().unwrap().push_back(Arc::clone(&conn));
                evq.cv.notify_one();
            }
        }
        for req in scratch.drain_plan() {
            evq.pool.recycle(req);
        }
        if ops > 0 {
            metrics.incr("exec_drains");
            metrics.add("exec_drained_ops", ops as u64);
        }
    }
}

/// Tell an over-`max_conns` client why it is being turned away: an
/// `at_capacity` error reply with the structured `rejected` marker
/// and `code`, then a clean close (FIN first, short receive drain so
/// pipelined bytes cannot turn the close into an RST that destroys
/// the reply).
fn reject_at_capacity(state: &ServerState, shard: usize, mut conn: TcpStream, max_conns: usize) {
    let _ = conn.set_nonblocking(false);
    if state.shards.len() > 1 {
        let _ = conn.write_all(state.shardmap_json(shard, true).to_string().as_bytes());
        let _ = conn.write_all(b"\n");
    }
    let error = if state.shards.len() > 1 {
        format!("shard {shard} at capacity ({max_conns} connections)")
    } else {
        format!("server at capacity ({max_conns} connections)")
    };
    let resp = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("rejected", Json::Bool(true)),
        ("code", Json::str(ErrorCode::AtCapacity.as_str())),
        ("error", Json::str(error)),
    ]);
    let _ = conn.write_all(resp.to_string().as_bytes());
    let _ = conn.write_all(b"\n");
    let _ = conn.shutdown(std::net::Shutdown::Write);
    conn.set_read_timeout(Some(Duration::from_millis(20))).ok();
    let mut sink = [0u8; 256];
    for _ in 0..4 {
        match conn.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_prefers_pending_then_conns_then_index() {
        let loads: Vec<Arc<IoLoad>> = (0..3).map(|_| Arc::new(IoLoad::new())).collect();
        // All idle: lowest index wins (a 1-thread shard stays on 0).
        assert_eq!(least_loaded(&loads), 0);
        // Pending ops dominate: thread 0 busy decoding, 1 has a pile
        // of quiet conns, 2 has one conn and nothing pending.
        loads[0].pending.store(5, Ordering::Relaxed);
        loads[1].conns.store(10, Ordering::Relaxed);
        loads[2].conns.store(1, Ordering::Relaxed);
        assert_eq!(least_loaded(&loads), 2);
        // Conns break pending ties.
        loads[2].pending.store(5, Ordering::Relaxed);
        loads[0].conns.store(2, Ordering::Relaxed);
        loads[1].pending.store(5, Ordering::Relaxed);
        assert_eq!(least_loaded(&loads), 2, "ties on pending fall to fewest conns");
    }

    #[test]
    fn buf_pool_recycles_requests_and_tracks_hits() {
        let pool = BufPool::new();
        // A miss mints a fresh buffer; recycling a drained request
        // turns the next acquisition into a hit with capacity kept.
        let mut s = pool.get_string();
        s.push_str("{\"op\":\"read\"}");
        let cap = s.capacity();
        pool.recycle(Request::Line(s));
        let s2 = pool.get_string();
        assert!(s2.is_empty(), "recycled strings come back cleared");
        assert!(s2.capacity() >= cap, "recycled strings keep their capacity");
        let mut b = pool.get_buf();
        b.extend_from_slice(b"payload");
        pool.recycle(Request::Frame(b));
        assert!(pool.get_buf().is_empty());
        assert_eq!(pool.hits.load(Ordering::Relaxed), 2);
        assert_eq!(pool.misses.load(Ordering::Relaxed), 2);
        // Non-buffer-carrying requests recycle to nothing, harmlessly.
        pool.recycle(Request::Overlong(9));
        pool.recycle(Request::BadFrame("x".into()));
    }

    #[test]
    fn buf_pool_drops_oversized_buffers() {
        let pool = BufPool::new();
        let mut s = pool.get_string();
        s.reserve(POOL_MAX_CAP + 1);
        s.push_str("big");
        pool.put_string(s);
        assert!(pool.strings.lock().unwrap().is_empty(), "oversized strings are dropped");
        let b = pool.get_buf();
        pool.put_buf(b);
        assert!(pool.bufs.lock().unwrap().is_empty(), "empty buffers are not pooled");
    }

    #[test]
    fn event_queue_gauges_start_empty() {
        let evq = EventQueue::new(2);
        assert_eq!(evq.pending_ops(), 0);
        assert_eq!(evq.open_conns(), 0);
        assert_eq!(evq.io_live.load(Ordering::Relaxed), 2);
    }
}
