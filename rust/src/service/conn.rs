//! The event-driven connection layer: many sockets, few threads.
//!
//! The legacy design (kept behind [`ConnMode::Threads`]) spawns one
//! handler thread per connection and leases that thread a funnel tid
//! for the connection's lifetime — so a shard can serve at most
//! `workers` clients at once, the opposite of the many-client regime
//! aggregating funnels are built for. This module removes the
//! ceiling: a small pool of I/O threads polls many non-blocking
//! sockets (via the `sync`-layer [`PollSet`] wrapper over `poll(2)` —
//! no tokio/mio), decodes complete request lines into per-connection
//! pending batches, and a fixed set of **funnel executors** — the
//! only tid holders, executor `e` owns tid `1 + e` — drains those
//! batches through the ordinary `handle_request` path. Funnel thread
//! tables stay sized for `workers + FOREIGN_TIDS + 1` tids no matter
//! how many thousands of sockets are open, and the more connections
//! are active, the more ops each executor sweep carries into the
//! funnels per wake-up — exactly the batch-size regime the paper's
//! one-FAA-per-batch amortization wants.
//!
//! Flow control is bounded end to end: at most `max_conns` open
//! connections per shard (excess connects get a clean `at_capacity`
//! error reply, not a silent drop) and at most `max_pending` decoded
//! requests in flight per shard (beyond it the I/O threads stop
//! reading, pushing back through TCP instead of buffering without
//! bound).
//!
//! Shutdown drains: on stop, each I/O thread performs one final read
//! pass (catching requests already in kernel buffers), the executors
//! finish every queued batch, and the I/O threads flush the remaining
//! responses before closing — so a graceful shutdown (or even a
//! `crash()` in tests) never swallows an accepted request. The
//! persist flusher is unaffected: executors journal at the same
//! combining points as the old per-connection handlers, so WAL batch
//! boundaries still track funnel group commits, not socket lifetimes.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::sync::poll::PollSet;
use crate::util::json::Json;

use super::error::{error_json, service_err, ErrorCode};
use super::ServerState;

/// Which connection core a server runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnMode {
    /// The multiplexed event-driven core (the default).
    Event,
    /// The legacy thread-per-connection core with per-connection tid
    /// leases (one release's worth of compatibility escape hatch).
    Threads,
}

impl ConnMode {
    pub fn parse(s: &str) -> Option<ConnMode> {
        match s {
            "event" => Some(ConnMode::Event),
            "threads" => Some(ConnMode::Threads),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ConnMode::Event => "event",
            ConnMode::Threads => "threads",
        }
    }
}

/// Connection-layer configuration (per shard).
#[derive(Clone, Debug)]
pub struct ConnOpts {
    pub mode: ConnMode,
    /// I/O poller threads per shard (event mode only). Thread 0 also
    /// owns the shard's listener.
    pub io_threads: usize,
    /// Open-connection ceiling per shard (event mode only); excess
    /// connects are rejected with an `at_capacity` error reply.
    pub max_conns: usize,
    /// Decoded-but-unexecuted request ceiling per shard (event mode
    /// only); beyond it the I/O threads stop reading and TCP
    /// backpressure reaches the clients.
    pub max_pending: usize,
}

impl Default for ConnOpts {
    fn default() -> Self {
        ConnOpts { mode: ConnMode::Event, io_threads: 1, max_conns: 1024, max_pending: 4096 }
    }
}

impl ConnOpts {
    /// The event-driven default.
    pub fn event() -> Self {
        Self::default()
    }

    /// The legacy thread-per-connection core.
    pub fn threads() -> Self {
        ConnOpts { mode: ConnMode::Threads, ..Self::default() }
    }
}

/// Longest accepted request line (1 MiB). A line beyond it is a
/// protocol error and closes the connection — without a bound one
/// newline-less client would grow a buffer forever.
const MAX_LINE: usize = 1 << 20;
/// Read chunk size and per-connection read rounds per poll wake-up
/// (bounded so one firehose connection cannot starve its siblings).
const READ_CHUNK: usize = 4096;
const READ_ROUNDS: usize = 16;
/// Connections one executor sweep drains per wake-up; the sweep is
/// the batch whose occupancy `exec_drained_ops / exec_drains`
/// reports.
const SWEEP: usize = 64;

/// Per-shard state shared between the I/O threads and the executors.
pub(super) struct EventQueue {
    /// Connections with decoded requests awaiting an executor.
    run: Mutex<VecDeque<Arc<ConnShared>>>,
    cv: Condvar,
    /// Decoded-but-unexecuted requests across the shard (the
    /// backpressure gauge).
    pending_ops: AtomicUsize,
    /// Open connections across the shard's I/O threads.
    conn_count: AtomicUsize,
    /// I/O threads that have not yet finished their shutdown read
    /// pass; executors only exit once it reaches zero with an empty
    /// run queue, so nothing decoded is ever dropped.
    io_live: AtomicUsize,
    next_id: AtomicU64,
}

impl EventQueue {
    pub(super) fn new(io_threads: usize) -> Self {
        EventQueue {
            run: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            pending_ops: AtomicUsize::new(0),
            conn_count: AtomicUsize::new(0),
            io_live: AtomicUsize::new(io_threads.max(1)),
            next_id: AtomicU64::new(0),
        }
    }

    /// Decoded requests currently awaiting execution (a gauge, not a
    /// counter — surfaces in per-shard cluster stats).
    pub(super) fn pending_ops(&self) -> usize {
        self.pending_ops.load(Ordering::Relaxed)
    }

    /// Currently open connections on this shard.
    pub(super) fn open_conns(&self) -> usize {
        self.conn_count.load(Ordering::Relaxed)
    }
}

/// The half of a connection both sides touch: executors append
/// responses and re-schedule; I/O threads enqueue decoded requests
/// and flush output. The `scheduled` flag guarantees a connection
/// sits in the run queue at most once, which also serializes
/// execution per connection — responses keep request order.
struct ConnShared {
    writer: TcpStream,
    wake: Arc<WakePing>,
    /// Bytes written by executors but not yet accepted by the socket.
    out: Mutex<Vec<u8>>,
    /// Decoded requests awaiting execution, in arrival order.
    requests: Mutex<VecDeque<Request>>,
    scheduled: AtomicBool,
    /// Peer finished sending (EOF/read error); drain, then reap.
    read_closed: AtomicBool,
    /// Write side failed; nothing further can be delivered.
    dead: AtomicBool,
}

/// One decoded unit of a connection's request stream. Keeping
/// malformed lines *in the queue* — instead of replying to them from
/// the I/O thread — preserves the pipelining contract: every request
/// gets exactly one reply, in the order the requests were sent, even
/// when some of them are garbage.
enum Request {
    /// A complete request line, ready for `handle_request`.
    Line(String),
    /// A line that exceeded [`MAX_LINE`] (bytes seen so far, for the
    /// error reply). The line is dropped through its newline —
    /// immediately if it arrived terminated, via the connection's
    /// discard mode otherwise — so framing stays intact and the
    /// connection lives on.
    Overlong(usize),
}

impl ConnShared {
    /// Queue `bytes` for this connection and push them as far as the
    /// socket will take them right now; leftovers wait for POLLOUT
    /// (the wake tells the owning I/O thread to start watching).
    fn send(&self, bytes: &[u8]) {
        if self.dead.load(Ordering::Acquire) {
            return;
        }
        self.out.lock().unwrap().extend_from_slice(bytes);
        self.flush();
        if !self.out.lock().unwrap().is_empty() {
            self.wake.wake();
        }
    }

    /// Write as much buffered output as the non-blocking socket
    /// accepts. Called by executors (opportunistically, right after a
    /// batch) and by I/O threads (on POLLOUT); the `out` lock makes
    /// the writes atomic with respect to each other.
    fn flush(&self) {
        let mut out = self.out.lock().unwrap();
        let mut written = 0;
        while written < out.len() {
            match (&self.writer).write(&out[written..]) {
                Ok(0) => {
                    self.dead.store(true, Ordering::Release);
                    break;
                }
                Ok(n) => written += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead.store(true, Ordering::Release);
                    break;
                }
            }
        }
        if self.dead.load(Ordering::Acquire) {
            out.clear();
        } else {
            out.drain(..written);
        }
    }

    /// Fully drained and idle (or beyond saving)?
    fn quiesced(&self) -> bool {
        self.dead.load(Ordering::Acquire)
            || (!self.scheduled.load(Ordering::Acquire)
                && self.requests.lock().unwrap().is_empty()
                && self.out.lock().unwrap().is_empty())
    }
}

/// Put a connection on the run queue unless it is already there.
fn schedule(evq: &EventQueue, conn: &Arc<ConnShared>) {
    if !conn.scheduled.swap(true, Ordering::AcqRel) {
        evq.run.lock().unwrap().push_back(Arc::clone(conn));
        evq.cv.notify_one();
    }
}

/// A self-wake channel: a loopback TCP pair (std-only — no pipe FFI)
/// whose read end sits in the I/O thread's poll set. Anyone holding
/// the write end can interrupt a `poll(2)` sleep.
struct WakePing {
    tx: TcpStream,
}

impl WakePing {
    fn wake(&self) {
        // One byte is enough; WouldBlock means wakes are already
        // pending, which serves the same purpose.
        let _ = (&self.tx).write(&[1u8]);
    }
}

fn wake_pair() -> std::io::Result<(WakePing, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let tx = TcpStream::connect(listener.local_addr()?)?;
    let (rx, _) = listener.accept()?;
    tx.set_nonblocking(true)?;
    tx.set_nodelay(true).ok();
    rx.set_nonblocking(true)?;
    Ok((WakePing { tx }, rx))
}

/// Spawn one shard's event core: `io_threads` pollers (thread 0 owns
/// the listener) plus `workers` funnel executors. All threads exit on
/// the server stop flag after the drain protocol described in the
/// module docs.
pub(super) fn spawn_event_core(
    state: &Arc<ServerState>,
    shard: usize,
    listener: TcpListener,
    opts: &ConnOpts,
    workers: usize,
) -> std::io::Result<Vec<std::thread::JoinHandle<()>>> {
    let evq = Arc::clone(
        state.shards[shard].evq.as_ref().expect("event core needs the shard's EventQueue"),
    );
    let io_n = opts.io_threads.max(1);
    let mut wakes = Vec::with_capacity(io_n);
    let mut rxs = Vec::with_capacity(io_n);
    let mut inboxes: Vec<Inbox> = Vec::with_capacity(io_n);
    for _ in 0..io_n {
        let (tx, rx) = wake_pair()?;
        wakes.push(Arc::new(tx));
        rxs.push(rx);
        inboxes.push(Arc::new(Mutex::new(Vec::new())));
    }
    let mut threads = Vec::with_capacity(io_n + workers);
    let mut listener = Some(listener);
    for (t, rx) in rxs.into_iter().enumerate() {
        let io = IoThread {
            state: Arc::clone(state),
            shard,
            evq: Arc::clone(&evq),
            listener: if t == 0 { listener.take() } else { None },
            wake_rx: rx,
            wake: Arc::clone(&wakes[t]),
            inbox: Arc::clone(&inboxes[t]),
            inboxes: inboxes.clone(),
            wakes: wakes.clone(),
            opts: opts.clone(),
            conns: Vec::new(),
        };
        threads.push(std::thread::spawn(move || io.run()));
    }
    for e in 0..workers.max(1) {
        let state = Arc::clone(state);
        let evq = Arc::clone(&evq);
        // Executors are the shard's only funnel tid holders:
        // executor `e` owns tid `1 + e` outright (tid 0 stays
        // reserved for in-process callers, the foreign pool above
        // `workers` still serves forwarded ops).
        let tid = 1 + e;
        threads.push(std::thread::spawn(move || executor_loop(&state, shard, tid, &evq)));
    }
    Ok(threads)
}

type Inbox = Arc<Mutex<Vec<(u64, TcpStream)>>>;

/// A connection owned by one I/O thread.
struct IoConn {
    stream: TcpStream,
    /// Bytes read but not yet terminated by a newline.
    buf: Vec<u8>,
    /// Mid-discard of an overlong line: swallow bytes (unbuffered)
    /// until the next newline restores framing. The error reply was
    /// already queued when the cap tripped.
    discarding: bool,
    shared: Arc<ConnShared>,
}

struct IoThread {
    state: Arc<ServerState>,
    shard: usize,
    evq: Arc<EventQueue>,
    /// Thread 0 owns the shard listener; the rest only poll conns.
    listener: Option<TcpListener>,
    wake_rx: TcpStream,
    wake: Arc<WakePing>,
    inbox: Inbox,
    inboxes: Vec<Inbox>,
    wakes: Vec<Arc<WakePing>>,
    opts: ConnOpts,
    conns: Vec<IoConn>,
}

impl IoThread {
    fn run(mut self) {
        let mut set = PollSet::new();
        while !self.state.stopping() {
            set.clear();
            let listener_slot = self.listener.as_ref().map(|l| set.push(l, true, false));
            let wake_slot = set.push(&self.wake_rx, true, false);
            // Backpressure: past `max_pending` decoded requests, stop
            // reading everywhere on this shard; TCP receive windows
            // fill and the clients feel it. Output still flushes, so
            // the executors drain the backlog and reads resume.
            let stalled = self.evq.pending_ops() >= self.opts.max_pending.max(1);
            if stalled {
                self.state.shards[self.shard].metrics.incr("backpressure_stalls");
            }
            let mut conn_slots = Vec::with_capacity(self.conns.len());
            for c in &self.conns {
                let read = !stalled
                    && !c.shared.read_closed.load(Ordering::Acquire)
                    && !c.shared.dead.load(Ordering::Acquire);
                let write = !c.shared.out.lock().unwrap().is_empty();
                conn_slots.push(set.push(&c.stream, read, write));
            }
            let _ = set.poll(50);
            if self.state.stopping() {
                break;
            }
            if set.readable(wake_slot) {
                self.drain_wake();
            }
            for (i, slot) in conn_slots.into_iter().enumerate() {
                if set.readable(slot) {
                    self.read_conn(i);
                }
                if set.writable(slot) {
                    self.conns[i].shared.flush();
                }
            }
            if let Some(slot) = listener_slot {
                if set.readable(slot) {
                    self.accept_round();
                }
            }
            self.adopt_inbox();
            self.reap();
        }
        self.drain_and_close();
    }

    fn drain_wake(&self) {
        let mut sink = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut sink) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock: drained
            }
        }
    }

    /// Accept everything the listener has ready, admitting up to
    /// `max_conns` per shard and rejecting the rest with a clean
    /// `at_capacity` reply (never a silent drop).
    fn accept_round(&mut self) {
        let Some(listener) = &self.listener else { return };
        loop {
            let conn = match listener.accept() {
                Ok((conn, _)) => conn,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock or transient: next poll retries
            };
            let metrics = &self.state.shards[self.shard].metrics;
            metrics.incr("connections");
            if self.evq.open_conns() >= self.opts.max_conns.max(1) {
                metrics.incr("rejected");
                reject_at_capacity(&self.state, self.shard, conn, self.opts.max_conns.max(1));
                continue;
            }
            self.evq.conn_count.fetch_add(1, Ordering::AcqRel);
            metrics.incr("conn_open");
            let id = self.evq.next_id.fetch_add(1, Ordering::Relaxed);
            let t = (id as usize) % self.inboxes.len();
            self.inboxes[t].lock().unwrap().push((id, conn));
            if t != 0 {
                self.wakes[t].wake();
            }
        }
    }

    /// Take ownership of connections the acceptor routed here.
    fn adopt_inbox(&mut self) {
        let adopted: Vec<(u64, TcpStream)> = self.inbox.lock().unwrap().drain(..).collect();
        for (_, stream) in adopted {
            if stream.set_nonblocking(true).is_err() {
                self.evq.conn_count.fetch_sub(1, Ordering::AcqRel);
                self.state.shards[self.shard].metrics.incr("conn_closed");
                continue;
            }
            stream.set_nodelay(true).ok();
            let writer = match stream.try_clone() {
                Ok(w) => w,
                Err(_) => {
                    self.evq.conn_count.fetch_sub(1, Ordering::AcqRel);
                    self.state.shards[self.shard].metrics.incr("conn_closed");
                    continue;
                }
            };
            let shared = Arc::new(ConnShared {
                writer,
                wake: Arc::clone(&self.wake),
                out: Mutex::new(Vec::new()),
                requests: Mutex::new(VecDeque::new()),
                scheduled: AtomicBool::new(false),
                read_closed: AtomicBool::new(false),
                dead: AtomicBool::new(false),
            });
            // Sharded servers greet on connect (same wire contract as
            // the legacy core); single-shard servers stay silent.
            if self.state.shards.len() > 1 {
                let mut greeting =
                    self.state.shardmap_json(self.shard, true).to_string().into_bytes();
                greeting.push(b'\n');
                shared.send(&greeting);
            }
            self.conns.push(IoConn { stream, buf: Vec::new(), discarding: false, shared });
        }
    }

    /// Non-blocking read rounds for one connection: pull what the
    /// kernel has, split complete lines into the request queue, and
    /// schedule the connection for an executor.
    fn read_conn(&mut self, i: usize) {
        let c = &mut self.conns[i];
        if c.shared.read_closed.load(Ordering::Acquire) || c.shared.dead.load(Ordering::Acquire)
        {
            return;
        }
        let mut chunk = [0u8; READ_CHUNK];
        for _ in 0..READ_ROUNDS {
            match (&c.stream).read(&mut chunk) {
                Ok(0) => {
                    c.shared.read_closed.store(true, Ordering::Release);
                    break;
                }
                Ok(n) => {
                    c.buf.extend_from_slice(&chunk[..n]);
                    if n < READ_CHUNK {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    c.shared.read_closed.store(true, Ordering::Release);
                    break;
                }
            }
        }
        let mut pushed = 0usize;
        loop {
            if c.discarding {
                // The head of the buffer is the tail of an overlong
                // line (already answered); swallow through its newline.
                match c.buf.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        c.buf.drain(..=pos);
                        c.discarding = false;
                    }
                    None => {
                        c.buf.clear();
                        break;
                    }
                }
            }
            let Some(pos) = c.buf.iter().position(|&b| b == b'\n') else {
                if c.buf.len() > MAX_LINE {
                    // Cap tripped mid-line: queue the error *in
                    // position* and discard until the next newline —
                    // requests pipelined behind the oversized line
                    // still get answered, in order.
                    c.shared
                        .requests
                        .lock()
                        .unwrap()
                        .push_back(Request::Overlong(c.buf.len()));
                    pushed += 1;
                    c.buf.clear();
                    c.discarding = true;
                }
                break;
            };
            let line: Vec<u8> = c.buf.drain(..=pos).collect();
            if line.len() > MAX_LINE {
                // Oversized but newline-terminated within this read:
                // same in-position error, framing already intact.
                c.shared.requests.lock().unwrap().push_back(Request::Overlong(line.len() - 1));
                pushed += 1;
                continue;
            }
            let text = String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
            if text.trim().is_empty() {
                continue;
            }
            c.shared.requests.lock().unwrap().push_back(Request::Line(text));
            pushed += 1;
        }
        if pushed > 0 {
            self.evq.pending_ops.fetch_add(pushed, Ordering::AcqRel);
            schedule(&self.evq, &c.shared);
        }
    }

    /// Drop connections that are gone and fully drained.
    fn reap(&mut self) {
        let evq = &self.evq;
        let metrics = &self.state.shards[self.shard].metrics;
        self.conns.retain(|c| {
            let gone = c.shared.dead.load(Ordering::Acquire)
                || (c.shared.read_closed.load(Ordering::Acquire) && c.shared.quiesced());
            if gone {
                evq.conn_count.fetch_sub(1, Ordering::AcqRel);
                metrics.incr("conn_closed");
            }
            !gone
        });
    }

    /// Shutdown: one final read pass catches requests already sitting
    /// in kernel buffers, then executors are released (`io_live`),
    /// then responses flush until every connection is quiet (bounded
    /// by a deadline so a stuck peer cannot hang `shutdown()`).
    fn drain_and_close(mut self) {
        for i in 0..self.conns.len() {
            self.read_conn(i);
        }
        self.evq.io_live.fetch_sub(1, Ordering::AcqRel);
        self.evq.cv.notify_all();
        let deadline = Instant::now() + Duration::from_secs(3);
        while Instant::now() < deadline {
            for c in &self.conns {
                c.shared.flush();
            }
            if self.conns.iter().all(|c| c.shared.quiesced()) {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// One funnel executor: sweep up to [`SWEEP`] scheduled connections
/// per wake-up and run their queued requests on this executor's tid.
/// The sweep is the drain the occupancy metrics describe — under many
/// active connections each wake-up carries many ops into the funnels.
fn executor_loop(state: &Arc<ServerState>, shard: usize, tid: usize, evq: &EventQueue) {
    loop {
        let mut batch: Vec<Arc<ConnShared>> = Vec::new();
        {
            let mut q = evq.run.lock().unwrap();
            loop {
                while batch.len() < SWEEP {
                    match q.pop_front() {
                        Some(c) => batch.push(c),
                        None => break,
                    }
                }
                if !batch.is_empty() {
                    break;
                }
                if state.stopping() && evq.io_live.load(Ordering::Acquire) == 0 {
                    return;
                }
                let (guard, _) = evq.cv.wait_timeout(q, Duration::from_millis(25)).unwrap();
                q = guard;
            }
        }
        let mut ops = 0usize;
        for conn in batch {
            let lines: Vec<Request> = conn.requests.lock().unwrap().drain(..).collect();
            if !lines.is_empty() {
                let mut out = Vec::new();
                for req in &lines {
                    // Every queued request — valid, failing, or
                    // malformed — produces exactly one reply here, in
                    // arrival order; a bad op in the middle of a
                    // pipelined batch never shifts or aborts the
                    // replies behind it.
                    let resp = match req {
                        Request::Line(line) => {
                            match super::handle_request(state, shard, tid, line) {
                                Ok(json) => json,
                                Err(e) => error_json(&e),
                            }
                        }
                        Request::Overlong(len) => error_json(&service_err(
                            ErrorCode::Protocol,
                            format!("request line exceeds {MAX_LINE} bytes ({len} received)"),
                        )),
                    };
                    out.extend_from_slice(resp.to_string().as_bytes());
                    out.push(b'\n');
                }
                evq.pending_ops.fetch_sub(lines.len(), Ordering::AcqRel);
                ops += lines.len();
                conn.send(&out);
            }
            // Re-arm: clear the scheduled flag, then re-check — a
            // producer that pushed between the drain and the clear
            // skipped its own schedule (the flag was still set), so
            // the re-check re-queues; the swap keeps it single-entry.
            conn.scheduled.store(false, Ordering::Release);
            let more = !conn.requests.lock().unwrap().is_empty();
            if more && !conn.scheduled.swap(true, Ordering::AcqRel) {
                evq.run.lock().unwrap().push_back(Arc::clone(&conn));
                evq.cv.notify_one();
            }
        }
        if ops > 0 {
            let metrics = &state.shards[shard].metrics;
            metrics.incr("exec_drains");
            metrics.add("exec_drained_ops", ops as u64);
        }
    }
}

/// Tell an over-`max_conns` client why it is being turned away: an
/// `at_capacity` error reply with the structured `rejected` marker
/// and `code`, then a clean close (FIN first, short receive drain so
/// pipelined bytes cannot turn the close into an RST that destroys
/// the reply).
fn reject_at_capacity(state: &ServerState, shard: usize, mut conn: TcpStream, max_conns: usize) {
    let _ = conn.set_nonblocking(false);
    if state.shards.len() > 1 {
        let _ = conn.write_all(state.shardmap_json(shard, true).to_string().as_bytes());
        let _ = conn.write_all(b"\n");
    }
    let error = if state.shards.len() > 1 {
        format!("shard {shard} at capacity ({max_conns} connections)")
    } else {
        format!("server at capacity ({max_conns} connections)")
    };
    let resp = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("rejected", Json::Bool(true)),
        ("code", Json::str(ErrorCode::AtCapacity.as_str())),
        ("error", Json::str(error)),
    ]);
    let _ = conn.write_all(resp.to_string().as_bytes());
    let _ = conn.write_all(b"\n");
    let _ = conn.shutdown(std::net::Shutdown::Write);
    conn.set_read_timeout(Some(Duration::from_millis(20))).ok();
    let mut sink = [0u8; 256];
    for _ in 0..4 {
        match conn.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conn_mode_parses_and_labels() {
        assert_eq!(ConnMode::parse("event"), Some(ConnMode::Event));
        assert_eq!(ConnMode::parse("threads"), Some(ConnMode::Threads));
        assert_eq!(ConnMode::parse("fibers"), None);
        assert_eq!(ConnMode::Event.label(), "event");
        assert_eq!(ConnMode::Threads.label(), "threads");
    }

    #[test]
    fn wake_pair_interrupts_a_poll() {
        let (tx, rx) = wake_pair().unwrap();
        let mut set = PollSet::new();
        let slot = set.push(&rx, true, false);
        tx.wake();
        assert!(set.poll(1000).unwrap() >= 1);
        assert!(set.readable(slot));
    }

    #[test]
    fn event_queue_gauges_start_empty() {
        let evq = EventQueue::new(2);
        assert_eq!(evq.pending_ops(), 0);
        assert_eq!(evq.open_conns(), 0);
        assert_eq!(evq.io_live.load(Ordering::Relaxed), 2);
    }
}
