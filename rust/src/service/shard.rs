//! Shards: independent contention domains for the registry service.
//!
//! The paper's thesis is that one hot memory word cannot absorb every
//! thread's fetch&adds; PR 3's registry recreated the same bottleneck
//! one level up — every object behind one listener, one tid space,
//! one resize controller. A [`Shard`] is the unit that breaks that
//! up: it owns its *own* [`Registry`], listener port, event core,
//! foreign-tid pool, [`Metrics`], and resize-controller thread, so
//! unrelated objects never share a listener, a lock domain, or a
//! controller walk (the shard-per-contention-domain design of
//! *Sharded Elimination and Combining*, PAPERS.md).
//!
//! Names route to shards by **FNV-1a 64** hash ([`shard_of`]); the
//! parent `service` module is the router that owns the shard map and
//! the cross-shard operations, while clients that have seen the
//! `shardmap` line talk to the owning shard's port directly — the hot
//! path never crosses a shard boundary.

use std::sync::{Arc, Mutex};

use super::metrics::Metrics;
use super::registry::Registry;
use super::ServerState;

/// The hash scheme advertised in the `shardmap` line. Clients must
/// use the same function or they will knock on the wrong door (the
/// server still answers — it forwards in-process — but the hot path
/// stops being shard-local).
pub const SHARD_HASH_SCHEME: &str = "fnv1a64";

/// FNV-1a 64-bit hash of a byte string (also the WAL frame checksum
/// of the persistence layer — see [`super::persist`]).
pub fn fnv1a64_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64-bit hash of an object name.
pub fn fnv1a64(name: &str) -> u64 {
    fnv1a64_bytes(name.as_bytes())
}

/// The shard an object name routes to: `fnv1a64(name) % shards`.
pub fn shard_of(name: &str, shards: usize) -> usize {
    if shards <= 1 {
        0
    } else {
        (fnv1a64(name) % shards as u64) as usize
    }
}

/// How many funnel thread ids each shard reserves for *foreign*
/// operations — requests accepted on another shard but owned here
/// (legacy or mis-routed clients, forwarded in-process). Every object
/// is built for `workers + FOREIGN_TIDS + 1` tids: the event core's
/// executor tids, this foreign pool, and the reserved in-process
/// tid 0 — independent of the shard count, so funnel per-thread
/// tables no longer scale with `shards × workers`.
pub const FOREIGN_TIDS: usize = 2;

/// A funnel thread-id lease pool handing out ids from a fixed range
/// `start..start + capacity`. Executor tids (`1..=workers`) are owned
/// statically by the event core's executor threads; the pool a shard
/// actually leases from at runtime is the foreign pool
/// (`workers+1..=workers+FOREIGN_TIDS`, leased per forwarded
/// operation). Tid 0 is reserved for in-process callers — boot,
/// recovery seeding, benchmarks embedding the server.
pub(super) struct TidLease {
    free: Mutex<Vec<usize>>,
    pub(super) start: usize,
    pub(super) capacity: usize,
}

impl TidLease {
    pub(super) fn new(capacity: usize) -> Self {
        Self::with_range(1, capacity)
    }

    pub(super) fn with_range(start: usize, capacity: usize) -> Self {
        Self {
            free: Mutex::new((start..start + capacity).rev().collect()),
            start,
            capacity,
        }
    }

    pub(super) fn lease(&self) -> Option<usize> {
        self.free.lock().unwrap().pop()
    }

    pub(super) fn release(&self, lease: usize) {
        debug_assert!(lease >= self.start && lease < self.start + self.capacity);
        self.free.lock().unwrap().push(lease);
    }
}

/// One registry shard.
pub struct Shard {
    /// Position in the shard map (and the port-layout offset).
    pub index: usize,
    /// The TCP port this shard's listener is bound to.
    pub port: u16,
    /// This shard's slice of the namespace.
    pub registry: Registry,
    /// Shard-level counters (connections, rejections, requests,
    /// forwarded); per-object traffic lives on each entry.
    pub metrics: Metrics,
    /// This shard's durability log (WAL + snapshots), when the
    /// service runs with a `data_dir`.
    pub log: Option<std::sync::Arc<super::persist::ShardLog>>,
    /// The event core's shared run queue + gauges (`None` only during
    /// construction; `serve` installs it before the listeners open).
    pub(super) evq: Option<std::sync::Arc<super::conn::EventQueue>>,
    /// Small pool of tids for forwarded operations (see
    /// [`FOREIGN_TIDS`]); leased per op, not per connection.
    pub(super) foreign: TidLease,
}

impl Shard {
    pub(super) fn new(index: usize, port: u16, registry: Registry, workers: usize) -> Self {
        Self {
            index,
            port,
            registry,
            metrics: Metrics::new(),
            log: None,
            evq: None,
            foreign: TidLease::with_range(workers + 1, FOREIGN_TIDS),
        }
    }

    /// Lease a foreign tid for one forwarded operation, spinning
    /// until the pool has one free. Safe against deadlock: every
    /// foreign lease is held only for the span of a single data-plane
    /// op (never across a wait on another lease), so a full pool
    /// always drains.
    pub(super) fn lease_foreign(&self) -> ForeignLease<'_> {
        let mut waited = false;
        loop {
            if let Some(tid) = self.foreign.lease() {
                return ForeignLease { shard: self, tid };
            }
            if !waited {
                waited = true;
                self.metrics.incr("foreign_waits");
            }
            std::thread::yield_now();
        }
    }
}

/// Guard for a leased foreign tid; returns it on drop (including when
/// the forwarded op panics).
pub(super) struct ForeignLease<'a> {
    shard: &'a Shard,
    pub(super) tid: usize,
}

impl Drop for ForeignLease<'_> {
    fn drop(&mut self) {
        self.shard.foreign.release(self.tid);
    }
}

/// Spawn this shard's resize-controller thread: walk the shard's own
/// registry and apply each object's policy to its contention window
/// every poll period. Sleeps in short slices so shutdown never waits
/// on a long configured period.
pub(super) fn spawn_controller(
    state: Arc<ServerState>,
    shard: usize,
    period: std::time::Duration,
) -> std::thread::JoinHandle<()> {
    let slice = period.min(std::time::Duration::from_millis(20));
    std::thread::spawn(move || loop {
        let mut slept = std::time::Duration::ZERO;
        while slept < period {
            if state.stopping() {
                return;
            }
            let chunk = slice.min(period - slept);
            std::thread::sleep(chunk);
            slept += chunk;
        }
        if state.stopping() {
            return;
        }
        for entry in state.shards[shard].registry.list() {
            entry.poll();
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_reference_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for shards in [1, 2, 4, 7] {
            for name in ["tickets", "jobs", "orders", "a", "zz-9"] {
                let s = shard_of(name, shards);
                assert!(s < shards, "{name} -> {s} out of range for {shards}");
                assert_eq!(s, shard_of(name, shards), "routing must be deterministic");
            }
        }
        assert_eq!(shard_of("anything", 1), 0);
        assert_eq!(shard_of("anything", 0), 0);
    }

    #[test]
    fn names_spread_across_shards() {
        // Not a uniformity proof — just that the hash doesn't collapse
        // a realistic name population onto one shard.
        let shards = 4;
        let mut hit = vec![false; shards];
        for i in 0..32 {
            hit[shard_of(&format!("object-{i}"), shards)] = true;
        }
        assert!(hit.iter().all(|h| *h), "32 names left a shard empty: {hit:?}");
    }

    #[test]
    fn tid_lease_roundtrip() {
        let pool = TidLease::new(2);
        let a = pool.lease().unwrap();
        let b = pool.lease().unwrap();
        assert_ne!(a, b);
        assert!(pool.lease().is_none(), "capacity 2");
        pool.release(a);
        assert_eq!(pool.lease(), Some(a));
    }

    #[test]
    fn fnv1a64_bytes_matches_str() {
        for s in ["", "a", "foobar", "shard-routing"] {
            assert_eq!(fnv1a64(s), fnv1a64_bytes(s.as_bytes()));
        }
    }

    #[test]
    fn foreign_pool_is_disjoint_from_connection_leases() {
        // workers = 3: connection tids 1..=3, foreign tids 4..=5,
        // tid 0 reserved — objects need workers + FOREIGN_TIDS + 1.
        let workers = 3;
        let conns = TidLease::new(workers);
        let foreign = TidLease::with_range(workers + 1, FOREIGN_TIDS);
        let mut seen = std::collections::BTreeSet::new();
        while let Some(t) = conns.lease() {
            assert!((1..=workers).contains(&t));
            assert!(seen.insert(t));
        }
        while let Some(t) = foreign.lease() {
            assert!((workers + 1..=workers + FOREIGN_TIDS).contains(&t));
            assert!(seen.insert(t), "foreign tid collided with a lease");
        }
        assert_eq!(seen.len(), workers + FOREIGN_TIDS);
        assert!(!seen.contains(&0), "tid 0 stays reserved for in-process callers");
    }

    #[test]
    fn foreign_lease_guard_returns_tid() {
        let shard = Shard::new(0, 0, Registry::new(4), 1);
        let first = {
            let lease = shard.lease_foreign();
            assert!(lease.tid >= 2, "foreign range starts after the connection pool");
            lease.tid
        };
        // Returned on drop: leasing again hands the same pool back.
        let again = shard.lease_foreign();
        let _second = shard.lease_foreign();
        let _ = again.tid;
        let _ = first;
    }
}
