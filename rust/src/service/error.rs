//! Typed service errors with machine-readable wire codes.
//!
//! Every error reply the server writes carries a `code` field next to
//! the human-readable `error` message (`{"ok":false,"error":...,
//! "code":"no_such_object"}`). Message text is unchanged from earlier
//! releases so old clients that substring-match keep working, while
//! new clients key decisions (retry on capacity, evict on I/O death)
//! off the enum instead of prose.
//!
//! The binary protocol carries the same enum as a one-byte response
//! status ([`super::frame::code_to_byte`]) followed by the identical
//! message text, so an error is the same typed value on either wire.

use std::fmt;

/// The machine-readable error classes the wire protocol exposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorCode {
    /// The named object does not exist on this shard.
    NoSuchObject,
    /// The object exists but is the wrong kind for the op (counter vs queue).
    WrongKind,
    /// The server (or one shard) has no room: connection slots or
    /// funnel capacity are exhausted. Retryable.
    AtCapacity,
    /// An enqueue item is outside the encodable range or reserved.
    ItemTooLarge,
    /// A direct-quota or durable-range budget was exhausted.
    QuotaExceeded,
    /// Malformed request, unknown op, or invalid argument.
    Protocol,
    /// A transport-level failure (client-side only; never sent on the wire).
    Io,
}

impl ErrorCode {
    /// The wire spelling carried in the reply's `code` field.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::NoSuchObject => "no_such_object",
            ErrorCode::WrongKind => "wrong_kind",
            ErrorCode::AtCapacity => "at_capacity",
            ErrorCode::ItemTooLarge => "item_too_large",
            ErrorCode::QuotaExceeded => "quota_exceeded",
            ErrorCode::Protocol => "protocol",
            ErrorCode::Io => "io",
        }
    }

    /// Parse a wire `code` field; unknown spellings map to `Protocol`
    /// so newer servers stay usable from this client.
    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "no_such_object" => ErrorCode::NoSuchObject,
            "wrong_kind" => ErrorCode::WrongKind,
            "at_capacity" => ErrorCode::AtCapacity,
            "item_too_large" => ErrorCode::ItemTooLarge,
            "quota_exceeded" => ErrorCode::QuotaExceeded,
            "io" => ErrorCode::Io,
            _ => ErrorCode::Protocol,
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A service error: a code plus the human-readable message that goes
/// in (or came from) the wire reply's `error` field.
#[derive(Debug, Clone)]
pub struct ServiceError {
    pub code: ErrorCode,
    pub message: String,
}

impl ServiceError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        ServiceError { code, message: message.into() }
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Display is the wire message only: server reply text must not
        // change when an error is wrapped/unwrapped through anyhow.
        f.write_str(&self.message)
    }
}

impl std::error::Error for ServiceError {}

/// Build an `anyhow::Error` carrying a typed [`ServiceError`].
pub fn service_err(code: ErrorCode, message: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(ServiceError::new(code, message))
}

/// The code attached to an error chain, defaulting to `Protocol` for
/// untyped errors (every pre-existing `anyhow!` site). This is how
/// callers key retry/evict decisions off a `Result` from the client
/// API without string-matching.
pub fn code_of(err: &anyhow::Error) -> ErrorCode {
    match err.downcast_ref::<ServiceError>() {
        Some(se) => se.code,
        None => ErrorCode::Protocol,
    }
}

/// The wire shape of an error reply: the unchanged human-readable
/// `error` text plus the machine-readable `code`.
pub(crate) fn error_json(err: &anyhow::Error) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(err.to_string())),
        ("code", Json::str(code_of(err).as_str())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip_through_wire_spelling() {
        for code in [
            ErrorCode::NoSuchObject,
            ErrorCode::WrongKind,
            ErrorCode::AtCapacity,
            ErrorCode::ItemTooLarge,
            ErrorCode::QuotaExceeded,
            ErrorCode::Protocol,
            ErrorCode::Io,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), code);
        }
        // Unknown spellings from a future server degrade to Protocol.
        assert_eq!(ErrorCode::parse("heat_death"), ErrorCode::Protocol);
    }

    #[test]
    fn display_is_the_bare_message() {
        let err = service_err(ErrorCode::NoSuchObject, "no object named \"x\"");
        assert_eq!(err.to_string(), "no object named \"x\"");
        assert_eq!(code_of(&err), ErrorCode::NoSuchObject);
    }

    #[test]
    fn untyped_errors_default_to_protocol() {
        let err = anyhow::anyhow!("some legacy failure");
        assert_eq!(code_of(&err), ErrorCode::Protocol);
    }
}
