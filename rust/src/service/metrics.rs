//! A small lock-free metrics registry (named monotonic counters).
//!
//! Fixed set of slots allocated on first use behind a spinlocked name
//! table; increments afterwards are a single relaxed atomic add, so
//! the hot path never takes the lock.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::sync::SpinLock;

const MAX_COUNTERS: usize = 64;

/// Counter registry.
pub struct Metrics {
    names: SpinLock<Vec<&'static str>>,
    slots: Vec<AtomicU64>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            names: SpinLock::new(Vec::new()),
            slots: (0..MAX_COUNTERS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn slot_of(&self, name: &'static str) -> usize {
        {
            let names = self.names.lock();
            if let Some(i) = names.iter().position(|n| *n == name) {
                return i;
            }
        }
        let mut names = self.names.lock();
        if let Some(i) = names.iter().position(|n| *n == name) {
            return i;
        }
        let i = names.len();
        assert!(i < MAX_COUNTERS, "too many metric names");
        names.push(name);
        i
    }

    /// Increment `name` by one.
    pub fn incr(&self, name: &'static str) {
        self.add(name, 1);
    }

    /// Add `v` to `name`.
    pub fn add(&self, name: &'static str, v: u64) {
        let i = self.slot_of(name);
        self.slots[i].fetch_add(v, Ordering::Relaxed);
    }

    /// Read one counter.
    pub fn get(&self, name: &'static str) -> u64 {
        let names = self.names.lock();
        match names.iter().position(|n| *n == name) {
            Some(i) => self.slots[i].load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        let names = self.names.lock();
        names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.to_string(), self.slots[i].load(Ordering::Relaxed)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn incr_and_get() {
        let m = Metrics::new();
        m.incr("a");
        m.incr("a");
        m.add("b", 5);
        assert_eq!(m.get("a"), 2);
        assert_eq!(m.get("b"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn snapshot_contains_all() {
        let m = Metrics::new();
        m.incr("x");
        m.incr("y");
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap["x"], 1);
    }

    #[test]
    fn concurrent_increments_sum() {
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        m.incr("hot");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.get("hot"), 40_000);
    }
}
