//! The registry service: a deployable wrapper around the library.
//!
//! A TCP server holding a concurrent [`Registry`] of **named
//! objects** — elastic-funnel counters (monotonic ticket/sequence
//! dispensers, the classic fetch-and-add application) and
//! funnel-backed FIFO queues (LCRQ/PRQ/MSQ, with `lcrq+elastic`
//! queues riding resizable funnel ring indices). One resize
//! controller thread walks *all* registered objects, applying each
//! object's [`WidthPolicy`] to its live contention window; `stats`
//! reports independent per-object width and contention counters, and
//! `resize`/`policy` reconfigure any single object at runtime.
//!
//! Each accepted connection leases a funnel thread id for its
//! lifetime; when all `workers` slots are leased, further connections
//! are rejected with an error line instead of breaching the funnels'
//! thread bound. Requests flagged `priority` use `Fetch&AddDirect`
//! (§4.4), giving latency-critical callers the fast path without
//! hurting others.
//!
//! Wire protocol: one JSON object per line. `name` defaults to the
//! boot counter `"tickets"`; items must be integers below 2⁵³ (JSON
//! numbers are doubles).
//!
//! ```text
//! → {"op":"take","count":3}                    ← {"ok":true,"start":17,"count":3}
//! → {"op":"take","count":1,"priority":true}
//! → {"op":"read"}                              ← {"ok":true,"value":20}
//! → {"op":"create","name":"jobs","kind":"queue","backend":"lcrq+elastic"}
//! → {"op":"enqueue","name":"jobs","item":7}    ← {"ok":true}
//! → {"op":"dequeue","name":"jobs"}             ← {"ok":true,"item":7}
//! → {"op":"list"}                              ← {"ok":true,"count":2,"objects":[...]}
//! → {"op":"stats","name":"jobs"}               ← {"ok":true,...counters...}
//! → {"op":"resize","width":4}                  ← {"ok":true,"width":4,"previous":6}
//! → {"op":"policy","policy":"aimd"}            ← {"ok":true,"policy":"aimd","width":1}
//! → {"op":"delete","name":"jobs"}              ← {"ok":true,"deleted":"jobs"}
//! ```

pub mod metrics;
pub mod registry;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::config::ObjectManifest;
use crate::faa::WidthPolicy;
use crate::util::json::Json;
use metrics::Metrics;
pub use registry::{ObjectEntry, Registry, DEFAULT_OBJECT};

/// The funnel thread-id lease pool: one id per concurrent connection.
/// Ids are `1..=capacity`; id 0 is reserved for in-process callers
/// (boot, benchmarks embedding the server).
struct TidLease {
    free: Mutex<Vec<usize>>,
    capacity: usize,
}

impl TidLease {
    fn new(capacity: usize) -> Self {
        Self { free: Mutex::new((1..=capacity).rev().collect()), capacity }
    }

    fn lease(&self) -> Option<usize> {
        self.free.lock().unwrap().pop()
    }

    fn release(&self, tid: usize) {
        debug_assert!(tid >= 1 && tid <= self.capacity);
        self.free.lock().unwrap().push(tid);
    }
}

/// Returns a leased tid to the pool when dropped — including when the
/// connection handler panics, so a crashed handler cannot permanently
/// shrink the server's connection capacity.
struct LeaseGuard {
    state: Arc<ServerState>,
    tid: usize,
}

impl Drop for LeaseGuard {
    fn drop(&mut self) {
        self.state.tids.release(self.tid);
    }
}

/// Shared server state.
struct ServerState {
    registry: Registry,
    /// Server-level counters (connections, rejections, requests);
    /// per-object traffic lives on each [`ObjectEntry`].
    metrics: Metrics,
    stop: AtomicBool,
    tids: TidLease,
}

/// Handle used to control a running server.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    state: Arc<ServerState>,
    threads: Vec<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ServerHandle {
    /// Request shutdown and join all workers. The accept loop polls a
    /// non-blocking listener and connection handlers use bounded
    /// reads, so no wake-up connection is needed — shutdown cannot be
    /// raced by a nudge landing on the wrong thread.
    pub fn shutdown(mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // The accept loop has exited, so no new connection threads can
        // appear; drain the ones still running.
        let conns: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for t in conns {
            let _ = t.join();
        }
    }
}

/// Configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub addr: String,
    /// Maximum concurrent client connections (the tid lease pool);
    /// connections beyond it are rejected with an error line.
    pub workers: usize,
    /// Initial active width per sign for the default counter.
    pub aggregators: usize,
    /// Width policy of the default counter.
    pub policy: WidthPolicy,
    /// Aggregator slot capacity per sign (elastic ceiling) for the
    /// default counter.
    pub max_aggregators: usize,
    /// Controller poll period in milliseconds (0 disables the
    /// controller thread; `resize`/`policy` ops still work).
    pub resize_interval_ms: u64,
    /// Objects pre-created at boot besides the default counter.
    pub objects: Vec<ObjectManifest>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        let s = crate::config::ServiceSettings::default();
        Self {
            addr: s.addr,
            workers: s.workers,
            aggregators: s.aggregators,
            policy: WidthPolicy::parse(&s.width_policy)
                .unwrap_or(WidthPolicy::Fixed(s.aggregators)),
            max_aggregators: s.max_aggregators,
            resize_interval_ms: s.resize_interval_ms,
            objects: s.objects,
        }
    }
}

impl ServeOpts {
    /// Old-style fixed-width options (no adaptive resizing): the
    /// default counter stays at `aggregators` wide.
    pub fn fixed(addr: &str, workers: usize, aggregators: usize) -> Self {
        Self {
            addr: addr.into(),
            workers,
            aggregators,
            policy: WidthPolicy::Fixed(aggregators),
            max_aggregators: aggregators.max(1),
            resize_interval_ms: 0,
            objects: Vec::new(),
        }
    }
}

/// Start the registry service; returns immediately with a handle.
pub fn serve(opts: &ServeOpts) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;

    // Every object is built for `workers + 1` thread ids: one per
    // leased connection, plus the reserved in-process tid 0.
    let workers = opts.workers.max(1);
    let registry = Registry::new(workers + 1);
    let _ = registry.create_counter(
        DEFAULT_OBJECT,
        opts.policy,
        opts.max_aggregators.max(opts.aggregators),
        Some(opts.aggregators),
    )?;
    for m in &opts.objects {
        registry
            .create(&m.name, &m.kind, &m.backend, None)
            .with_context(|| format!("boot object {:?}", m.name))?;
    }

    let state = Arc::new(ServerState {
        registry,
        metrics: Metrics::new(),
        stop: AtomicBool::new(false),
        tids: TidLease::new(workers),
    });
    let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

    // Resize controller: walk every registered object and apply its
    // policy to its contention window each poll period. Sleeps in
    // short slices so shutdown never waits on a long configured
    // period.
    let mut threads = Vec::new();
    if opts.resize_interval_ms > 0 {
        let state = Arc::clone(&state);
        let period = std::time::Duration::from_millis(opts.resize_interval_ms);
        let slice = period.min(std::time::Duration::from_millis(20));
        threads.push(std::thread::spawn(move || loop {
            let mut slept = std::time::Duration::ZERO;
            while slept < period {
                if state.stop.load(Ordering::SeqCst) {
                    return;
                }
                let chunk = slice.min(period - slept);
                std::thread::sleep(chunk);
                slept += chunk;
            }
            if state.stop.load(Ordering::SeqCst) {
                return;
            }
            for entry in state.registry.list() {
                entry.poll();
            }
        }));
    }

    // Accept loop: non-blocking polls bounded by the stop flag (the
    // explicit accept deadline that replaces the old wake-up-by-
    // connecting shutdown nudge).
    {
        let state = Arc::clone(&state);
        let conns = Arc::clone(&conns);
        threads.push(std::thread::spawn(move || loop {
            if state.stop.load(Ordering::SeqCst) {
                return;
            }
            let conn = match listener.accept() {
                Ok((conn, _)) => conn,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    continue;
                }
                Err(_) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    continue;
                }
            };
            state.metrics.incr("connections");
            let Some(tid) = state.tids.lease() else {
                // All funnel tids leased: reject instead of running a
                // connection on an out-of-range thread id.
                state.metrics.incr("rejected");
                let _ = reject_conn(conn, state.tids.capacity);
                continue;
            };
            let handler = {
                let state = Arc::clone(&state);
                std::thread::spawn(move || {
                    let _guard = LeaseGuard { state: Arc::clone(&state), tid };
                    let _ = handle_conn(&state, tid, conn);
                })
            };
            let mut held = conns.lock().unwrap();
            held.retain(|h| !h.is_finished());
            held.push(handler);
        }));
    }
    Ok(ServerHandle { addr, state, threads, conns })
}

/// Tell an over-capacity client why it is being dropped.
fn reject_conn(mut conn: TcpStream, capacity: usize) -> std::io::Result<()> {
    // Accepted sockets do not inherit the listener's non-blocking
    // mode on Linux, but make it explicit for portability.
    conn.set_nonblocking(false)?;
    let resp = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(format!("server at capacity ({capacity} connection slots)"))),
    ]);
    conn.write_all(resp.to_string().as_bytes())?;
    conn.write_all(b"\n")
}

fn handle_conn(state: &ServerState, tid: usize, conn: TcpStream) -> Result<()> {
    conn.set_nonblocking(false).ok();
    conn.set_nodelay(true).ok();
    // Bounded reads so a handler parked on an idle connection still
    // notices shutdown (otherwise `shutdown()` would hang on join).
    conn.set_read_timeout(Some(std::time::Duration::from_millis(200))).ok();
    let mut writer = conn.try_clone()?;
    let mut reader = BufReader::new(conn);
    // One buffer across iterations: a read timeout mid-line leaves the
    // bytes read so far in `line` (read_until semantics), so a slow
    // writer's request is completed by later reads instead of being
    // dropped and desyncing the line stream.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if !line.trim().is_empty() {
            let response = match handle_request(state, tid, &line) {
                Ok(json) => json,
                Err(e) => Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(e.to_string())),
                ]),
            };
            writer.write_all(response.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
        }
        line.clear();
    }
}

fn handle_request(state: &ServerState, tid: usize, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = req.get("op").and_then(Json::as_str).ok_or_else(|| anyhow!("missing op"))?;
    state.metrics.incr("requests");
    match op {
        // -- control plane -------------------------------------------------
        "create" => {
            let name = req
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("create needs a name"))?;
            let kind = req.get("kind").and_then(Json::as_str).unwrap_or("counter");
            // Empty backend → the kind's default, applied by create.
            let backend = req.get("backend").and_then(Json::as_str).unwrap_or("");
            let max_width =
                req.get("max_width").and_then(Json::as_u64).map(|w| w as usize);
            let entry = state.registry.create(name, kind, backend, max_width)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("name", Json::str(entry.name.clone())),
                ("kind", Json::str(entry.kind())),
                ("backend", Json::str(entry.backend.clone())),
            ]))
        }
        "delete" => {
            let name = req
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("delete needs a name"))?;
            state.registry.remove(name)?;
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("deleted", Json::str(name))]))
        }
        "list" => {
            let objects: Vec<Json> = state
                .registry
                .list()
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("name", Json::str(e.name.clone())),
                        ("kind", Json::str(e.kind())),
                        ("backend", Json::str(e.backend.clone())),
                    ])
                })
                .collect();
            let server: std::collections::BTreeMap<String, Json> = state
                .metrics
                .snapshot()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v as f64)))
                .collect();
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("count", Json::num(objects.len() as f64)),
                ("objects", Json::Arr(objects)),
                ("server", Json::Obj(server)),
            ]))
        }
        // -- data plane (namespaced; name defaults to the boot counter) ----
        _ => {
            let name = req.get("name").and_then(Json::as_str).unwrap_or(DEFAULT_OBJECT);
            let entry = state.registry.get(name)?;
            match op {
                "take" => {
                    let count =
                        req.get("count").and_then(Json::as_u64).unwrap_or(1).max(1);
                    let priority =
                        req.get("priority").and_then(Json::as_bool).unwrap_or(false);
                    let start = entry.take(tid, count, priority)?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("start", Json::num(start as f64)),
                        ("count", Json::num(count as f64)),
                    ]))
                }
                "read" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("value", Json::num(entry.read(tid)? as f64)),
                ])),
                "enqueue" => {
                    let item = req.get("item").and_then(Json::as_u64).ok_or_else(|| {
                        anyhow!("enqueue needs an item (non-negative integer)")
                    })?;
                    entry.enqueue(tid, item)?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true))]))
                }
                "dequeue" => Ok(match entry.dequeue(tid)? {
                    Some(item) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("item", Json::num(item as f64)),
                    ]),
                    None => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("empty", Json::Bool(true)),
                    ]),
                }),
                "stats" => {
                    entry.metrics.incr("stats");
                    let mut json = entry.stats_json();
                    if let Json::Obj(map) = &mut json {
                        map.insert(
                            "registry_objects".to_string(),
                            Json::num(state.registry.len() as f64),
                        );
                    }
                    Ok(json)
                }
                "resize" => {
                    let width = req
                        .get("width")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| anyhow!("resize needs a width"))?;
                    let (width, previous) = entry.resize(width as usize)?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("width", Json::num(width as f64)),
                        ("previous", Json::num(previous as f64)),
                    ]))
                }
                "policy" => {
                    let spec = req
                        .get("policy")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("policy needs a policy string"))?;
                    let policy = WidthPolicy::parse(spec)
                        .ok_or_else(|| anyhow!("unknown width policy {spec:?}"))?;
                    let width = entry.set_policy(policy)?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("policy", Json::str(policy.label())),
                        ("width", Json::num(width as f64)),
                    ]))
                }
                other => Err(anyhow!("unknown op {other:?}")),
            }
        }
    }
}

/// Minimal blocking client for the registry service. Un-named methods
/// address the boot counter ([`DEFAULT_OBJECT`]); `*_on` methods and
/// the queue ops are namespaced.
pub struct TicketClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TicketClient {
    pub fn connect(addr: &str) -> Result<TicketClient> {
        let conn = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        conn.set_nodelay(true).ok();
        let writer = conn.try_clone()?;
        Ok(TicketClient { reader: BufReader::new(conn), writer })
    }

    fn roundtrip(&mut self, req: Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(anyhow!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("?")
            ));
        }
        Ok(resp)
    }

    /// Create a named object (`kind`: `counter` | `queue`; `backend`:
    /// the spec grammar, empty for the kind's default).
    pub fn create(&mut self, name: &str, kind: &str, backend: &str) -> Result<()> {
        let mut pairs = vec![
            ("op", Json::str("create")),
            ("name", Json::str(name)),
            ("kind", Json::str(kind)),
        ];
        if !backend.is_empty() {
            pairs.push(("backend", Json::str(backend)));
        }
        self.roundtrip(Json::obj(pairs)).map(drop)
    }

    /// Delete a named object.
    pub fn delete(&mut self, name: &str) -> Result<()> {
        self.roundtrip(Json::obj(vec![
            ("op", Json::str("delete")),
            ("name", Json::str(name)),
        ]))
        .map(drop)
    }

    /// List registered objects as `(name, kind, backend)` triples.
    pub fn list(&mut self) -> Result<Vec<(String, String, String)>> {
        let resp = self.roundtrip(Json::obj(vec![("op", Json::str("list"))]))?;
        let objects = resp
            .get("objects")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing objects"))?;
        objects
            .iter()
            .map(|o| {
                let field = |k: &str| {
                    o.get(k)
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("object missing {k}"))
                };
                Ok((field("name")?, field("kind")?, field("backend")?))
            })
            .collect()
    }

    /// Enqueue `item` on a named queue.
    pub fn enqueue(&mut self, name: &str, item: u64) -> Result<()> {
        self.roundtrip(Json::obj(vec![
            ("op", Json::str("enqueue")),
            ("name", Json::str(name)),
            ("item", Json::num(item as f64)),
        ]))
        .map(drop)
    }

    /// Dequeue from a named queue (`None` when empty).
    pub fn dequeue(&mut self, name: &str) -> Result<Option<u64>> {
        let resp = self.roundtrip(Json::obj(vec![
            ("op", Json::str("dequeue")),
            ("name", Json::str(name)),
        ]))?;
        if resp.get("empty").and_then(Json::as_bool) == Some(true) {
            return Ok(None);
        }
        resp.get("item")
            .and_then(Json::as_u64)
            .map(Some)
            .ok_or_else(|| anyhow!("missing item"))
    }

    /// Take a contiguous range of `count` values from a named counter.
    pub fn take_on(&mut self, name: &str, count: u64, priority: bool) -> Result<u64> {
        let mut pairs = vec![
            ("op", Json::str("take")),
            ("name", Json::str(name)),
            ("count", Json::num(count as f64)),
        ];
        if priority {
            pairs.push(("priority", Json::Bool(true)));
        }
        let resp = self.roundtrip(Json::obj(pairs))?;
        resp.get("start").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing start"))
    }

    /// Take from the default counter; returns the range start.
    pub fn take(&mut self, count: u64, priority: bool) -> Result<u64> {
        self.take_on(DEFAULT_OBJECT, count, priority)
    }

    /// Read a named counter.
    pub fn read_on(&mut self, name: &str) -> Result<u64> {
        let resp = self.roundtrip(Json::obj(vec![
            ("op", Json::str("read")),
            ("name", Json::str(name)),
        ]))?;
        resp.get("value").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing value"))
    }

    pub fn read(&mut self) -> Result<u64> {
        self.read_on(DEFAULT_OBJECT)
    }

    /// Per-object stats for a named object.
    pub fn stats_on(&mut self, name: &str) -> Result<Json> {
        self.roundtrip(Json::obj(vec![
            ("op", Json::str("stats")),
            ("name", Json::str(name)),
        ]))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.stats_on(DEFAULT_OBJECT)
    }

    /// Set a named object's active width; returns the width in force.
    pub fn resize_on(&mut self, name: &str, width: u64) -> Result<u64> {
        let resp = self.roundtrip(Json::obj(vec![
            ("op", Json::str("resize")),
            ("name", Json::str(name)),
            ("width", Json::num(width as f64)),
        ]))?;
        resp.get("width").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing width"))
    }

    pub fn resize(&mut self, width: u64) -> Result<u64> {
        self.resize_on(DEFAULT_OBJECT, width)
    }

    /// Swap a named object's width policy (`fixed:<m>`, `sqrtp`,
    /// `aimd`).
    pub fn set_policy_on(&mut self, name: &str, policy: &str) -> Result<String> {
        let resp = self.roundtrip(Json::obj(vec![
            ("op", Json::str("policy")),
            ("name", Json::str(name)),
            ("policy", Json::str(policy)),
        ]))?;
        resp.get("policy")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("missing policy"))
    }

    pub fn set_policy(&mut self, policy: &str) -> Result<String> {
        self.set_policy_on(DEFAULT_OBJECT, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start() -> ServerHandle {
        serve(&ServeOpts::fixed("127.0.0.1:0", 3, 2)).unwrap()
    }

    #[test]
    fn tickets_are_disjoint_ranges() {
        let server = start();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = TicketClient::connect(&addr).unwrap();
                    let mut ranges = Vec::new();
                    for i in 0..50u64 {
                        let count = 1 + i % 4;
                        let start = c.take(count, i % 7 == 0).unwrap();
                        ranges.push((start, count));
                    }
                    ranges
                })
            })
            .collect();
        let mut all: Vec<(u64, u64)> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        // Ranges must tile [0, total) without overlap.
        let mut expected_start = 0u64;
        for (start, count) in all {
            assert_eq!(start, expected_start, "overlapping or gapped ticket ranges");
            expected_start = start + count;
        }
        server.shutdown();
    }

    #[test]
    fn read_and_stats_work() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.take(5, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 5);
        let stats = c.stats().unwrap();
        assert!(stats.get("take").and_then(Json::as_u64).unwrap_or(0) >= 1);
        assert_eq!(stats.get("name").and_then(Json::as_str), Some(DEFAULT_OBJECT));
        assert_eq!(stats.get("registry_objects").and_then(Json::as_u64), Some(1));
        server.shutdown();
    }

    #[test]
    fn resize_and_policy_ops_reconfigure_live() {
        let server = serve(&ServeOpts {
            max_aggregators: 8,
            resize_interval_ms: 0, // manual control only
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.resize(5).unwrap(), 5);
        assert_eq!(c.resize(100).unwrap(), 8, "clamped to capacity");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(8));
        assert_eq!(stats.get("max_width").and_then(Json::as_u64), Some(8));
        assert!(stats.get("resizes").and_then(Json::as_u64).unwrap_or(0) >= 2);
        // Policy swap applies immediately (fixed:3 forces the width).
        assert_eq!(c.set_policy("fixed:3").unwrap(), "fixed-3");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert!(c.set_policy("bogus").is_err());
        // Tickets still flow after reconfiguration.
        assert_eq!(c.take(2, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 2);
        server.shutdown();
    }

    #[test]
    fn stats_expose_contention_counters() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        for _ in 0..20 {
            c.take(1, false).unwrap();
        }
        let stats = c.stats().unwrap();
        let ops = stats.get("batched_ops").and_then(Json::as_u64).unwrap();
        let faas = stats.get("main_faas").and_then(Json::as_u64).unwrap();
        assert!(ops >= 20);
        assert!(faas <= ops, "ops ({ops}) must bound main F&As ({faas})");
        assert!(stats.get("avg_batch").is_some());
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-2"));
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.writer.write_all(b"{\"op\":\"nope\"}\n").unwrap();
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        // Connection stays usable.
        assert_eq!(c.take(1, false).unwrap(), 0);
        server.shutdown();
    }

    #[test]
    fn registry_ops_over_the_wire() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create("jobs", "queue", "lcrq+elastic:fixed:2").unwrap();
        c.create("orders", "counter", "").unwrap(); // kind default backend
        assert!(c.create("jobs", "queue", "").is_err(), "duplicate name");
        let listed = c.list().unwrap();
        let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, vec!["jobs", "orders", DEFAULT_OBJECT]);
        assert_eq!(listed[0].1, "queue");
        assert_eq!(listed[0].2, "lcrq+elastic:fixed:2");

        // Queue traffic, independent of the default counter.
        assert_eq!(c.dequeue("jobs").unwrap(), None);
        c.enqueue("jobs", 41).unwrap();
        c.enqueue("jobs", 42).unwrap();
        assert_eq!(c.dequeue("jobs").unwrap(), Some(41));
        // Named counter traffic.
        assert_eq!(c.take_on("orders", 3, false).unwrap(), 0);
        assert_eq!(c.read_on("orders").unwrap(), 3);
        assert_eq!(c.read().unwrap(), 0, "default counter untouched");

        // Kind mismatches and unknown names are clean errors.
        assert!(c.take_on("jobs", 1, false).is_err());
        assert!(c.enqueue(DEFAULT_OBJECT, 1).is_err());
        assert!(c.dequeue("ghost").is_err());

        // Per-object stats are independent.
        let jobs = c.stats_on("jobs").unwrap();
        assert_eq!(jobs.get("kind").and_then(Json::as_str), Some("queue"));
        assert_eq!(jobs.get("enqueue").and_then(Json::as_u64), Some(2));
        assert_eq!(jobs.get("active_width").and_then(Json::as_u64), Some(2));
        let orders = c.stats_on("orders").unwrap();
        assert_eq!(orders.get("take").and_then(Json::as_u64), Some(1));
        assert!(orders.get("enqueue").is_none());

        c.delete("jobs").unwrap();
        assert!(c.delete("jobs").is_err());
        assert_eq!(c.list().unwrap().len(), 2);
        server.shutdown();
    }

    #[test]
    fn queue_width_ops_ride_the_index_factory() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create("q", "queue", "lcrq+elastic:fixed:2").unwrap();
        assert_eq!(c.resize_on("q", 4).unwrap(), 4);
        assert_eq!(c.set_policy_on("q", "fixed:1").unwrap(), "fixed-1");
        let stats = c.stats_on("q").unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(1));
        // Non-elastic indices have no width controls.
        c.create("q2", "queue", "lcrq+hw").unwrap();
        assert!(c.resize_on("q2", 4).is_err());
        server.shutdown();
    }

    #[test]
    fn connections_beyond_lease_pool_rejected() {
        let server = serve(&ServeOpts::fixed("127.0.0.1:0", 1, 2)).unwrap();
        let addr = server.addr.to_string();
        let mut first = TicketClient::connect(&addr).unwrap();
        // Completing a request proves the only lease is held.
        assert_eq!(first.take(1, false).unwrap(), 0);
        // Read the rejection line without writing first (a write could
        // race the server-side close into an RST that drops the line).
        let second = TcpStream::connect(&addr).unwrap();
        let mut line = String::new();
        BufReader::new(second).read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("capacity"), "unexpected rejection: {err}");
        // The leased connection keeps working.
        assert_eq!(first.take(1, false).unwrap(), 1);
        server.shutdown();
    }

    #[test]
    fn manifest_objects_precreated_at_boot() {
        let server = serve(&ServeOpts {
            objects: vec![
                ObjectManifest {
                    name: "jobs".into(),
                    kind: "queue".into(),
                    backend: "lcrq+elastic".into(),
                },
                ObjectManifest {
                    name: "orders".into(),
                    kind: "counter".into(),
                    backend: "elastic:sqrtp".into(),
                },
            ],
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.list().unwrap().len(), 3);
        c.enqueue("jobs", 9).unwrap();
        assert_eq!(c.dequeue("jobs").unwrap(), Some(9));
        assert_eq!(c.take_on("orders", 2, false).unwrap(), 0);
        server.shutdown();
        // A manifest colliding with the boot counter fails loudly.
        let err = serve(&ServeOpts {
            objects: vec![ObjectManifest {
                name: DEFAULT_OBJECT.into(),
                kind: "counter".into(),
                backend: "elastic:aimd".into(),
            }],
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        });
        assert!(err.is_err());
    }
}
