//! The ticket service: a deployable wrapper around the library.
//!
//! A thread-pooled TCP server dispensing monotonically increasing
//! ticket ranges — the classic fetch-and-add application (distinct
//! ids, timestamps, sequence numbers). The hot path is one
//! `Fetch&Add(count)` on an Aggregating Funnel shared by all workers;
//! requests flagged `priority` use `Fetch&AddDirect` (§4.4), giving
//! latency-critical callers the fast path without hurting others.
//!
//! The ticket counter is an *elastic* Aggregating Funnel: a resize
//! controller thread periodically applies the configured
//! [`WidthPolicy`] to the funnel's contention window, so one deployment
//! serves both quiet and flash-crowd traffic; `stats` exposes the live
//! width and contention counters, and the `resize` / `policy` ops
//! reconfigure the subsystem at runtime without a restart.
//!
//! Wire protocol: one JSON object per line.
//!
//! ```text
//! → {"op":"take","count":3}            ← {"ok":true,"start":17,"count":3}
//! → {"op":"take","count":1,"priority":true}
//! → {"op":"read"}                      ← {"ok":true,"value":20}
//! → {"op":"stats"}                     ← {"ok":true,...counters...}
//! → {"op":"resize","width":4}          ← {"ok":true,"width":4,"previous":6}
//! → {"op":"policy","policy":"aimd"}    ← {"ok":true,"policy":"aimd"}
//! ```

pub mod metrics;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::faa::{ElasticAggFunnel, ElasticConfig, FetchAddObject, WidthPolicy};
use crate::util::json::Json;
use metrics::Metrics;

/// Shared server state.
struct ServerState {
    tickets: ElasticAggFunnel,
    /// Active width policy; swappable at runtime via the `policy` op.
    policy: Mutex<WidthPolicy>,
    metrics: Metrics,
    stop: AtomicBool,
    active_conns: AtomicUsize,
}

/// Handle used to control a running server.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    state: Arc<ServerState>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown and join all workers.
    pub fn shutdown(mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub addr: String,
    pub workers: usize,
    /// Initial active width per sign.
    pub aggregators: usize,
    /// Width policy the resize controller applies.
    pub policy: WidthPolicy,
    /// Aggregator slot capacity per sign (elastic ceiling).
    pub max_aggregators: usize,
    /// Controller poll period in milliseconds (0 disables the
    /// controller thread; `resize`/`policy` ops still work).
    pub resize_interval_ms: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        let s = crate::config::ServiceSettings::default();
        Self {
            addr: s.addr,
            workers: s.workers,
            aggregators: s.aggregators,
            policy: WidthPolicy::parse(&s.width_policy)
                .unwrap_or(WidthPolicy::Fixed(s.aggregators)),
            max_aggregators: s.max_aggregators,
            resize_interval_ms: s.resize_interval_ms,
        }
    }
}

impl ServeOpts {
    /// Old-style fixed-width options (no adaptive resizing): the
    /// funnel stays at `aggregators` wide.
    pub fn fixed(addr: &str, workers: usize, aggregators: usize) -> Self {
        Self {
            addr: addr.into(),
            workers,
            aggregators,
            policy: WidthPolicy::Fixed(aggregators),
            max_aggregators: aggregators.max(1),
            resize_interval_ms: 0,
        }
    }
}

/// Start the ticket server; returns immediately with a handle.
pub fn serve(opts: &ServeOpts) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("binding {}", opts.addr))?;
    let addr = listener.local_addr()?;
    // tid 0 is reserved for priority/direct operations issued by any
    // worker (direct ops never touch per-thread funnel state that
    // conflicts: they only hit Main and the tid-0 stats counters,
    // which we guard with the metrics registry instead).
    let funnel_threads = opts.workers + 1;
    let tickets = ElasticAggFunnel::with_config(
        ElasticConfig::new(funnel_threads)
            .with_max_width(opts.max_aggregators.max(opts.aggregators))
            .with_policy(opts.policy),
    );
    // `aggregators` is the explicit starting width regardless of what
    // the policy would pick on its own.
    tickets.resize(opts.aggregators);
    let state = Arc::new(ServerState {
        tickets,
        policy: Mutex::new(opts.policy),
        metrics: Metrics::new(),
        stop: AtomicBool::new(false),
        active_conns: AtomicUsize::new(0),
    });

    // Resize controller: apply the policy to the funnel's contention
    // window every poll period. Sleeps in short slices so shutdown
    // never waits on a long configured period.
    let mut threads = Vec::new();
    if opts.resize_interval_ms > 0 {
        let state = Arc::clone(&state);
        let period = std::time::Duration::from_millis(opts.resize_interval_ms);
        let slice = period.min(std::time::Duration::from_millis(20));
        threads.push(std::thread::spawn(move || loop {
            let mut slept = std::time::Duration::ZERO;
            while slept < period {
                if state.stop.load(Ordering::SeqCst) {
                    return;
                }
                let chunk = slice.min(period - slept);
                std::thread::sleep(chunk);
                slept += chunk;
            }
            if state.stop.load(Ordering::SeqCst) {
                return;
            }
            let policy = *state.policy.lock().unwrap();
            state.tickets.poll_policy(&policy);
        }));
    }

    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Arc::new(Mutex::new(rx));
    for w in 0..opts.workers {
        let rx = Arc::clone(&rx);
        let state = Arc::clone(&state);
        threads.push(std::thread::spawn(move || {
            let tid = w + 1; // funnel tid for this worker
            loop {
                let conn = match rx.lock().unwrap().recv() {
                    Ok(c) => c,
                    Err(_) => return,
                };
                if state.stop.load(Ordering::SeqCst) {
                    return;
                }
                state.active_conns.fetch_add(1, Ordering::Relaxed);
                let _ = handle_conn(&state, tid, conn);
                state.active_conns.fetch_sub(1, Ordering::Relaxed);
            }
        }));
    }
    {
        let state = Arc::clone(&state);
        threads.push(std::thread::spawn(move || {
            for conn in listener.incoming() {
                if state.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Ok(conn) = conn {
                    if tx.send(conn).is_err() {
                        return;
                    }
                }
            }
        }));
    }
    Ok(ServerHandle { addr, state, threads })
}

fn handle_conn(state: &ServerState, tid: usize, conn: TcpStream) -> Result<()> {
    conn.set_nodelay(true).ok();
    // Bounded reads so a worker parked on an idle connection still
    // notices shutdown (otherwise `shutdown()` would hang on join).
    conn.set_read_timeout(Some(std::time::Duration::from_millis(200))).ok();
    let mut writer = conn.try_clone()?;
    let mut reader = BufReader::new(conn);
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_request(state, tid, &line) {
            Ok(json) => json,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(e.to_string())),
            ]),
        };
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

fn handle_request(state: &ServerState, tid: usize, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = req.get("op").and_then(Json::as_str).ok_or_else(|| anyhow!("missing op"))?;
    match op {
        "take" => {
            let count = req.get("count").and_then(Json::as_u64).unwrap_or(1).max(1);
            let priority =
                req.get("priority").and_then(Json::as_bool).unwrap_or(false);
            let start = if priority {
                state.metrics.incr("take_priority");
                state.tickets.fetch_add_direct(tid, count as i64)
            } else {
                state.metrics.incr("take");
                state.tickets.fetch_add(tid, count as i64)
            };
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("start", Json::num(start as f64)),
                ("count", Json::num(count as f64)),
            ]))
        }
        "read" => {
            state.metrics.incr("read");
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("value", Json::num(state.tickets.read(tid) as f64)),
            ]))
        }
        "stats" => {
            let mut pairs = vec![("ok", Json::Bool(true))];
            let snap = state.metrics.snapshot();
            let stats = state.tickets.batch_stats();
            let extra = [
                ("main_faas".to_string(), stats.main_faas),
                ("batched_ops".to_string(), stats.ops),
                ("single_op_batches".to_string(), stats.single_op_batches),
                ("cas_failures".to_string(), stats.cas_failures),
                ("active_width".to_string(), state.tickets.active_width() as u64),
                ("max_width".to_string(), state.tickets.max_width() as u64),
                ("resizes".to_string(), state.tickets.resizes()),
            ];
            let mut obj = std::collections::BTreeMap::new();
            for (k, v) in pairs.drain(..) {
                obj.insert(k.to_string(), v);
            }
            for (k, v) in snap.into_iter().chain(extra) {
                obj.insert(k, Json::num(v as f64));
            }
            obj.insert("avg_batch".to_string(), Json::num(stats.avg_batch_size()));
            obj.insert(
                "width_policy".to_string(),
                Json::str(state.policy.lock().unwrap().label()),
            );
            Ok(Json::Obj(obj))
        }
        "resize" => {
            let width = req
                .get("width")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("resize needs a width"))? as usize;
            state.metrics.incr("resize");
            let previous = state.tickets.resize(width);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("width", Json::num(state.tickets.active_width() as f64)),
                ("previous", Json::num(previous as f64)),
            ]))
        }
        "policy" => {
            let spec = req
                .get("policy")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("policy needs a policy string"))?;
            let policy = WidthPolicy::parse(spec)
                .ok_or_else(|| anyhow!("unknown width policy {spec:?}"))?;
            state.metrics.incr("policy");
            *state.policy.lock().unwrap() = policy;
            // Apply once immediately so `resize_interval_ms = 0`
            // deployments still honour the change.
            state.tickets.poll_policy(&policy);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("policy", Json::str(policy.label())),
                ("width", Json::num(state.tickets.active_width() as f64)),
            ]))
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

/// Minimal blocking client for the ticket service.
pub struct TicketClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TicketClient {
    pub fn connect(addr: &str) -> Result<TicketClient> {
        let conn = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        conn.set_nodelay(true).ok();
        let writer = conn.try_clone()?;
        Ok(TicketClient { reader: BufReader::new(conn), writer })
    }

    fn roundtrip(&mut self, req: Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Err(anyhow!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("?")
            ));
        }
        Ok(resp)
    }

    /// Take a contiguous range of `count` tickets; returns the start.
    pub fn take(&mut self, count: u64, priority: bool) -> Result<u64> {
        let mut pairs = vec![
            ("op", Json::str("take")),
            ("count", Json::num(count as f64)),
        ];
        if priority {
            pairs.push(("priority", Json::Bool(true)));
        }
        let resp = self.roundtrip(Json::obj(pairs))?;
        resp.get("start").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing start"))
    }

    pub fn read(&mut self) -> Result<u64> {
        let resp = self.roundtrip(Json::obj(vec![("op", Json::str("read"))]))?;
        resp.get("value").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing value"))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(Json::obj(vec![("op", Json::str("stats"))]))
    }

    /// Set the funnel's active width; returns the width now in force.
    pub fn resize(&mut self, width: u64) -> Result<u64> {
        let resp = self.roundtrip(Json::obj(vec![
            ("op", Json::str("resize")),
            ("width", Json::num(width as f64)),
        ]))?;
        resp.get("width").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing width"))
    }

    /// Swap the width policy at runtime (`fixed:<m>`, `sqrtp`, `aimd`).
    pub fn set_policy(&mut self, policy: &str) -> Result<String> {
        let resp = self.roundtrip(Json::obj(vec![
            ("op", Json::str("policy")),
            ("policy", Json::str(policy)),
        ]))?;
        resp.get("policy")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("missing policy"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start() -> ServerHandle {
        serve(&ServeOpts::fixed("127.0.0.1:0", 3, 2)).unwrap()
    }

    #[test]
    fn tickets_are_disjoint_ranges() {
        let server = start();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = TicketClient::connect(&addr).unwrap();
                    let mut ranges = Vec::new();
                    for i in 0..50u64 {
                        let count = 1 + i % 4;
                        let start = c.take(count, i % 7 == 0).unwrap();
                        ranges.push((start, count));
                    }
                    ranges
                })
            })
            .collect();
        let mut all: Vec<(u64, u64)> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        // Ranges must tile [0, total) without overlap.
        let mut expected_start = 0u64;
        for (start, count) in all {
            assert_eq!(start, expected_start, "overlapping or gapped ticket ranges");
            expected_start = start + count;
        }
        server.shutdown();
    }

    #[test]
    fn read_and_stats_work() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.take(5, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 5);
        let stats = c.stats().unwrap();
        assert!(stats.get("take").and_then(Json::as_u64).unwrap_or(0) >= 1);
        server.shutdown();
    }

    #[test]
    fn resize_and_policy_ops_reconfigure_live() {
        let server = serve(&ServeOpts {
            max_aggregators: 8,
            resize_interval_ms: 0, // manual control only
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.resize(5).unwrap(), 5);
        assert_eq!(c.resize(100).unwrap(), 8, "clamped to capacity");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(8));
        assert_eq!(stats.get("max_width").and_then(Json::as_u64), Some(8));
        assert!(stats.get("resizes").and_then(Json::as_u64).unwrap_or(0) >= 2);
        // Policy swap applies immediately (fixed:3 forces the width).
        assert_eq!(c.set_policy("fixed:3").unwrap(), "fixed-3");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert!(c.set_policy("bogus").is_err());
        // Tickets still flow after reconfiguration.
        assert_eq!(c.take(2, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 2);
        server.shutdown();
    }

    #[test]
    fn stats_expose_contention_counters() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        for _ in 0..20 {
            c.take(1, false).unwrap();
        }
        let stats = c.stats().unwrap();
        let ops = stats.get("batched_ops").and_then(Json::as_u64).unwrap();
        let faas = stats.get("main_faas").and_then(Json::as_u64).unwrap();
        assert!(ops >= 20);
        assert!(faas <= ops, "ops ({ops}) must bound main F&As ({faas})");
        assert!(stats.get("avg_batch").is_some());
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-2"));
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.writer.write_all(b"{\"op\":\"nope\"}\n").unwrap();
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        // Connection stays usable.
        assert_eq!(c.take(1, false).unwrap(), 0);
        server.shutdown();
    }
}
