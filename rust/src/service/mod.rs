//! The registry service: a deployable wrapper around the library.
//!
//! A TCP server holding **named objects** — elastic-funnel counters
//! (monotonic ticket/sequence dispensers, the classic fetch-and-add
//! application) and funnel-backed FIFO queues (LCRQ/PRQ/MSQ, with
//! `lcrq+elastic` queues riding resizable funnel ring indices) —
//! spread across `S` independent [`Shard`]s. Each shard owns its own
//! [`Registry`], listener port, `workers`-sized tid-lease pool,
//! metrics, and resize-controller thread; object names route to
//! shards by FNV-1a hash ([`shard_of`]), so unrelated objects never
//! share an accept loop, a lock domain, or a cache line's worth of
//! registry state. This module is the thin router on top: it owns the
//! shard map, fans `list` and aggregate `stats` out across shards,
//! and forwards mis-routed single-object ops to the owning shard
//! in-process.
//!
//! On connect, a sharded server (S > 1) pushes one `shardmap` line
//! (shard count, hash scheme, per-shard ports) so clients route
//! follow-up requests straight to the owning shard's port — the hot
//! path never crosses a shard boundary. `shards = 1` servers send no
//! greeting and stay line-for-line wire-compatible with the pre-shard
//! protocol; un-named ops still route to the boot counter `tickets`.
//!
//! Each accepted connection leases a funnel thread id from its
//! shard's pool for its lifetime; when all `workers` slots are
//! leased, further connections on that shard are rejected with an
//! error line instead of breaching the funnels' thread bounds.
//! Requests flagged `priority` use `Fetch&AddDirect` (§4.4) subject
//! to the object's configurable direct-thread quota `d`: at most `d`
//! priority callers ride `Main` concurrently, the rest are demoted to
//! the funnel.
//!
//! Wire protocol: one JSON object per line. `name` defaults to the
//! boot counter `"tickets"`; items must be integers below 2⁵³ (JSON
//! numbers are doubles).
//!
//! ```text
//! → {"op":"take","count":3}                    ← {"ok":true,"start":17,"count":3}
//! → {"op":"take","count":1,"priority":true}
//! → {"op":"read"}                              ← {"ok":true,"value":20}
//! → {"op":"shardmap"}                          ← {"ok":true,"shardmap":true,"shards":4,"hash":"fnv1a64","base_port":7471,"ports":[...]}
//! → {"op":"create","name":"jobs","kind":"queue","backend":"lcrq+elastic"}
//! → {"op":"create","name":"vip","kind":"counter","direct_quota":2}
//! → {"op":"enqueue","name":"jobs","item":7}    ← {"ok":true}
//! → {"op":"dequeue","name":"jobs"}             ← {"ok":true,"item":7}
//! → {"op":"list"}                              ← {"ok":true,"count":2,"objects":[...]}   (all shards, sorted)
//! → {"op":"stats","name":"jobs"}               ← {"ok":true,...counters...}
//! → {"op":"stats","name":"*"}                  ← {"ok":true,"scope":"cluster",...}       (all shards, merged)
//! → {"op":"resize","width":4}                  ← {"ok":true,"width":4,"previous":6}
//! → {"op":"policy","policy":"aimd"}            ← {"ok":true,"policy":"aimd","width":1}
//! → {"op":"snapshot"}                          ← {"ok":true,"persist":true,"snapshots":[...]}  (persistent servers)
//! → {"op":"delete","name":"jobs"}              ← {"ok":true,"deleted":"jobs"}
//! ```
//!
//! With a `data_dir` configured, every shard owns a [`ShardLog`]
//! (WAL + snapshots, see [`persist`]): mutations journal their
//! *logical* effects at the combining points — one record per
//! group-commit window per object, not one per op — and a restart
//! recovers the full object set with monotonic counters and exact
//! queue multisets before the listeners open.

pub mod metrics;
pub mod persist;
pub mod registry;
pub mod shard;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::config::ObjectManifest;
use crate::faa::{BatchStats, WidthPolicy};
use crate::util::json::Json;
pub use persist::{PersistOpts, RecoveryReport, ShardLog};
pub use registry::{CreateOpts, ObjectEntry, Registry, DEFAULT_OBJECT};
pub use shard::{fnv1a64, fnv1a64_bytes, shard_of, Shard, FOREIGN_TIDS, SHARD_HASH_SCHEME};

/// Shared server state: the shard set plus the stop flag. The shards
/// live in one process, so cross-shard operations (`list`, aggregate
/// `stats`, forwarding a mis-routed op) are plain in-process walks —
/// no internal RPC.
pub(crate) struct ServerState {
    shards: Vec<Shard>,
    stop: AtomicBool,
}

impl ServerState {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// The shard that owns `name` under the advertised hash scheme.
    fn shard_for(&self, name: &str) -> &Shard {
        &self.shards[shard_of(name, self.shards.len())]
    }

    /// Resolve the owning shard for a request received on shard
    /// `via`. A legacy or mis-routed client is served anyway — the
    /// handler walks over to the owning shard in-process, leasing a
    /// tid from the owner's foreign pool for the op — but the hop is
    /// counted: a hot `forwarded` counter means the client is not
    /// using the shard map.
    fn route(&self, via: usize, name: &str) -> &Shard {
        let owner = self.shard_for(name);
        if owner.index != via {
            self.shards[via].metrics.incr("forwarded");
        }
        owner
    }

    /// The `shardmap` document: shard count, hash scheme and the
    /// per-shard port layout (`base_port` is `ports[0]`; with an
    /// explicit configured port the layout is `base_port + i`, with
    /// port 0 each shard binds its own ephemeral port, so `ports` is
    /// authoritative).
    fn shardmap_json(&self, via: usize, greeting: bool) -> Json {
        let ports: Vec<Json> = self.shards.iter().map(|s| Json::num(s.port as f64)).collect();
        let mut pairs = vec![
            ("ok", Json::Bool(true)),
            ("shardmap", Json::Bool(true)),
            ("shard", Json::num(via as f64)),
            ("shards", Json::num(self.shards.len() as f64)),
            ("hash", Json::str(SHARD_HASH_SCHEME)),
            ("base_port", Json::num(self.shards[0].port as f64)),
            ("ports", Json::Arr(ports)),
        ];
        if greeting {
            pairs.push(("greeting", Json::Bool(true)));
        }
        Json::obj(pairs)
    }
}

/// Handle used to control a running server.
pub struct ServerHandle {
    /// Shard 0's address (the `base_port` of the shard map; the only
    /// address for `shards = 1`).
    pub addr: std::net::SocketAddr,
    ports: Vec<u16>,
    state: Arc<ServerState>,
    threads: Vec<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ServerHandle {
    /// The per-shard port layout (length = shard count).
    pub fn shard_ports(&self) -> &[u16] {
        &self.ports
    }

    /// Request shutdown and join all workers. The accept loops poll
    /// non-blocking listeners and connection handlers use bounded
    /// reads, so no wake-up connection is needed — shutdown cannot be
    /// raced by a nudge landing on the wrong thread. On a persistent
    /// server, the final journal window is flushed and a snapshot
    /// written after every handler has drained, so a graceful
    /// shutdown loses nothing.
    pub fn shutdown(mut self) {
        self.halt();
        for (i, shard) in self.state.shards.iter().enumerate() {
            if let Some(log) = &shard.log {
                persist::flush_shard(&self.state, i);
                let _ = log.snapshot();
            }
        }
    }

    /// Test support: stop serving *without* the final flush/snapshot,
    /// simulating a crash. Whatever the WAL already holds (everything
    /// acked, in sync mode; everything up to the last group commit
    /// otherwise) is exactly what a restart recovers.
    pub fn crash(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // The accept loops have exited, so no new connection threads
        // can appear; drain the ones still running.
        let conns: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for t in conns {
            let _ = t.join();
        }
    }
}

/// Configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Shard 0's listen address. With an explicit port `p`, shard `i`
    /// binds `p + i`; with port 0 every shard binds its own ephemeral
    /// port (the `shardmap` line carries the actual layout).
    pub addr: String,
    /// Number of independent registry shards (1 = the pre-shard wire
    /// protocol, no greeting).
    pub shards: usize,
    /// Maximum concurrent client connections *per shard* (each
    /// shard's tid lease pool); connections beyond it are rejected
    /// with an error line.
    pub workers: usize,
    /// Initial active width per sign for the default counter.
    pub aggregators: usize,
    /// Width policy of the default counter.
    pub policy: WidthPolicy,
    /// Aggregator slot capacity per sign (elastic ceiling) for the
    /// default counter.
    pub max_aggregators: usize,
    /// Controller poll period in milliseconds (0 disables the
    /// per-shard controller threads; `resize`/`policy` ops still
    /// work).
    pub resize_interval_ms: u64,
    /// Objects pre-created at boot besides the default counter, each
    /// assigned to its owning shard by name hash.
    pub objects: Vec<ObjectManifest>,
    /// Durability: `Some` gives every shard a WAL + snapshot
    /// directory under `data_dir` and recovers from it at boot;
    /// `None` (the default) keeps the registry in-memory only.
    pub persist: Option<PersistOpts>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        let s = crate::config::ServiceSettings::default();
        Self {
            addr: s.addr,
            shards: s.shards,
            workers: s.workers,
            aggregators: s.aggregators,
            policy: WidthPolicy::parse(&s.width_policy)
                .unwrap_or(WidthPolicy::Fixed(s.aggregators)),
            max_aggregators: s.max_aggregators,
            resize_interval_ms: s.resize_interval_ms,
            objects: s.objects,
            persist: None,
        }
    }
}

impl ServeOpts {
    /// Old-style fixed-width options (no adaptive resizing, single
    /// shard): the default counter stays at `aggregators` wide.
    pub fn fixed(addr: &str, workers: usize, aggregators: usize) -> Self {
        Self {
            addr: addr.into(),
            shards: 1,
            workers,
            aggregators,
            policy: WidthPolicy::Fixed(aggregators),
            max_aggregators: aggregators.max(1),
            resize_interval_ms: 0,
            objects: Vec::new(),
            persist: None,
        }
    }

    /// `fixed`, with `shards` independent shards.
    pub fn sharded(addr: &str, shards: usize, workers: usize, aggregators: usize) -> Self {
        Self { shards: shards.max(1), ..Self::fixed(addr, workers, aggregators) }
    }
}

/// Start the registry service; returns immediately with a handle.
pub fn serve(opts: &ServeOpts) -> Result<ServerHandle> {
    let shard_count = opts.shards.max(1);
    let workers = opts.workers.max(1);
    let (host, base_port) = split_host_port(&opts.addr)?;

    // Bind every shard's listener up front so a port collision fails
    // the whole boot instead of leaving a half-listening server.
    let mut listeners = Vec::with_capacity(shard_count);
    for i in 0..shard_count {
        let bind = if base_port == 0 {
            format!("{host}:0")
        } else {
            // The documented layout is `base_port + i`; refuse a
            // layout that would run off the end of the port space
            // instead of wrapping into ephemeral binds.
            let port = u32::from(base_port) + i as u32;
            let port = u16::try_from(port).map_err(|_| {
                anyhow!("shard {i} port {port} exceeds 65535 (base {base_port}, {shard_count} shards)")
            })?;
            format!("{host}:{port}")
        };
        let listener =
            TcpListener::bind(&bind).with_context(|| format!("binding shard {i} on {bind}"))?;
        listener.set_nonblocking(true)?;
        listeners.push(listener);
    }
    let addr = listeners[0].local_addr()?;

    // Every object is built for `workers + FOREIGN_TIDS + 1` thread
    // ids: one per leased connection on *this* shard, the small
    // foreign pool that forwarded (legacy/mis-routed) ops lease per
    // operation, plus the reserved in-process tid 0. Per-object
    // per-thread funnel tables no longer scale with the shard count.
    let max_threads = workers + FOREIGN_TIDS + 1;
    if let Some(p) = &opts.persist {
        // Shard logs are bound to their slice of the hash space:
        // refuse to boot a data_dir with a different shard count.
        persist::check_layout(std::path::Path::new(&p.data_dir), shard_count)?;
    }
    let mut shards = Vec::with_capacity(shard_count);
    for (i, listener) in listeners.iter().enumerate() {
        let mut shard = Shard::new(
            i,
            listener.local_addr()?.port(),
            Registry::new(max_threads),
            workers,
        );
        if let Some(p) = &opts.persist {
            let dir = std::path::Path::new(&p.data_dir).join(format!("shard-{i}"));
            let log = Arc::new(
                ShardLog::open(&dir, p.sync_mode())
                    .with_context(|| format!("opening shard {i} durability log"))?,
            );
            shard.registry.set_log(Arc::clone(&log));
            shard.log = Some(log);
        }
        shards.push(shard);
    }
    let state = Arc::new(ServerState { shards, stop: AtomicBool::new(false) });

    // Recovery: re-create every durable object through the ordinary
    // BackendSpec path and seed counters/queues — before the accept
    // loops exist, so no connection ever observes a half-recovered
    // registry. Seeding runs on the reserved in-process tid 0.
    for shard in &state.shards {
        let Some(log) = &shard.log else { continue };
        let report = log.recovery();
        for (name, obj) in log.recovered_objects() {
            let entry = shard
                .registry
                .create(
                    &name,
                    &obj.kind,
                    &obj.backend,
                    CreateOpts {
                        max_width: obj.max_width,
                        direct_quota: None, // travels in the backend label
                        persist: true,
                    },
                )
                .with_context(|| format!("recovering object {name:?}"))?;
            if obj.kind == "counter" {
                entry
                    .seed_counter(obj.counter)
                    .with_context(|| format!("seeding counter {name:?}"))?;
            } else {
                for item in &obj.items {
                    entry
                        .seed_queue_item(*item)
                        .with_context(|| format!("seeding queue {name:?}"))?;
                }
            }
            shard.metrics.incr("recovered_objects");
        }
        shard.metrics.add("wal_replayed", report.replayed as u64);
        if report.torn_tail {
            shard.metrics.incr("wal_torn_tail");
        }
    }

    // Boot objects land on their owning shards: the default counter
    // by the hash of its well-known name, manifest objects likewise.
    // Objects recovery already re-created keep their durable state
    // (the running system outranks the boot manifest).
    let default_owner = state.shard_for(DEFAULT_OBJECT);
    if default_owner.registry.get(DEFAULT_OBJECT).is_err() {
        default_owner.registry.create_counter(
            DEFAULT_OBJECT,
            opts.policy,
            opts.max_aggregators.max(opts.aggregators),
            Some(opts.aggregators),
            None,
            true,
        )?;
    } else {
        default_owner.metrics.incr("boot_objects_recovered");
    }
    for m in &opts.objects {
        let owner = state.shard_for(&m.name);
        if owner.registry.get(&m.name).is_ok() {
            owner.metrics.incr("boot_objects_recovered");
            continue;
        }
        owner
            .registry
            .create(
                &m.name,
                &m.kind,
                &m.backend,
                CreateOpts {
                    max_width: None,
                    direct_quota: m.direct_quota,
                    persist: m.persist,
                },
            )
            .with_context(|| format!("boot object {:?}", m.name))?;
    }

    // Compact immediately: the recovered + boot state becomes the
    // snapshot baseline and the replayed WAL is truncated, so the log
    // only ever holds one boot's worth of tail.
    for shard in &state.shards {
        if let Some(log) = &shard.log {
            log.snapshot().with_context(|| format!("boot snapshot, shard {}", shard.index))?;
        }
    }

    let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let mut threads = Vec::new();
    if opts.resize_interval_ms > 0 {
        let period = std::time::Duration::from_millis(opts.resize_interval_ms);
        for i in 0..shard_count {
            threads.push(shard::spawn_controller(Arc::clone(&state), i, period));
        }
    }
    if let Some(p) = &opts.persist {
        // In sync mode the flusher only handles periodic snapshots.
        if !p.sync_mode() || p.snapshot_interval_ms > 0 {
            for i in 0..shard_count {
                threads.push(persist::spawn_flusher(Arc::clone(&state), i, p.clone()));
            }
        }
    }
    for (i, listener) in listeners.into_iter().enumerate() {
        threads.push(shard::spawn_accept_loop(
            Arc::clone(&state),
            i,
            listener,
            Arc::clone(&conns),
        ));
    }
    let ports = state.shards.iter().map(|s| s.port).collect();
    Ok(ServerHandle { addr, ports, state, threads, conns })
}

/// Split `host:port` (the port may be 0 for ephemeral binding).
fn split_host_port(addr: &str) -> Result<(String, u16)> {
    let (host, port) = addr
        .rsplit_once(':')
        .ok_or_else(|| anyhow!("address {addr:?} must be host:port"))?;
    let port: u16 = port.parse().with_context(|| format!("bad port in {addr:?}"))?;
    Ok((host.to_string(), port))
}

/// Route one request line received on shard `via` by a connection
/// holding shard-local funnel tid `tid` (forwarded ops swap it for a
/// tid leased from the owning shard's foreign pool).
fn handle_request(state: &ServerState, via: usize, tid: usize, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = req.get("op").and_then(Json::as_str).ok_or_else(|| anyhow!("missing op"))?;
    state.shards[via].metrics.incr("requests");
    match op {
        // -- shard map ------------------------------------------------------
        "shardmap" => Ok(state.shardmap_json(via, false)),
        // -- durability -----------------------------------------------------
        "snapshot" => snapshot_all(state),
        // -- control plane (routed to the owning shard) ---------------------
        "create" => {
            let name = req
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("create needs a name"))?;
            let kind = req.get("kind").and_then(Json::as_str).unwrap_or("counter");
            // Empty backend → the kind's default, applied by create.
            let backend = req.get("backend").and_then(Json::as_str).unwrap_or("");
            let create_opts = CreateOpts {
                max_width: req.get("max_width").and_then(Json::as_u64).map(|w| w as usize),
                direct_quota: req
                    .get("direct_quota")
                    .and_then(Json::as_u64)
                    .map(|d| d as usize),
                persist: req.get("persist").and_then(Json::as_bool).unwrap_or(true),
            };
            let owner = state.route(via, name);
            let entry = owner.registry.create(name, kind, backend, create_opts)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("name", Json::str(entry.name.clone())),
                ("kind", Json::str(entry.kind())),
                ("backend", Json::str(entry.backend.clone())),
                ("shard", Json::num(owner.index as f64)),
            ]))
        }
        "delete" => {
            let name = req
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("delete needs a name"))?;
            let owner = state.route(via, name);
            owner.registry.remove(name)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("deleted", Json::str(name)),
                ("shard", Json::num(owner.index as f64)),
            ]))
        }
        // -- cross-shard fan-out --------------------------------------------
        "list" => Ok(list_all(state)),
        "stats" if req.get("name").and_then(Json::as_str) == Some("*") => {
            Ok(cluster_stats(state))
        }
        // -- data plane (namespaced; name defaults to the boot counter) ----
        _ => {
            let name = req.get("name").and_then(Json::as_str).unwrap_or(DEFAULT_OBJECT);
            let owner = state.route(via, name);
            let entry = owner.registry.get(name)?;
            // A forwarded op must not reuse this connection's tid on
            // the owning shard's objects (objects are sized for the
            // owner's own leases): borrow a tid from the owner's
            // foreign pool for the span of this one operation — but
            // only for the ops that actually enter a funnel
            // (`stats`/`resize`/`policy` never touch per-thread
            // state, so they must not occupy the small pool).
            let needs_tid = matches!(op, "take" | "read" | "enqueue" | "dequeue");
            let foreign;
            let tid = if owner.index == via || !needs_tid {
                tid
            } else {
                foreign = owner.lease_foreign();
                foreign.tid
            };
            match op {
                "take" => {
                    let count =
                        req.get("count").and_then(Json::as_u64).unwrap_or(1).max(1);
                    // Sanity-bound one request's range: a huge count
                    // could push a counter past 2^53 in one shot,
                    // where JSON (wire and WAL alike) stops being
                    // exact — then a recovered value could round
                    // below an acked grant.
                    if count > MAX_TAKE_COUNT {
                        return Err(anyhow!(
                            "count {count} exceeds the per-request limit {MAX_TAKE_COUNT}"
                        ));
                    }
                    let priority =
                        req.get("priority").and_then(Json::as_bool).unwrap_or(false);
                    let start = entry.take(tid, count, priority)?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("start", Json::num(start as f64)),
                        ("count", Json::num(count as f64)),
                    ]))
                }
                "read" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("value", Json::num(entry.read(tid)? as f64)),
                ])),
                "enqueue" => {
                    let item = req.get("item").and_then(Json::as_u64).ok_or_else(|| {
                        anyhow!("enqueue needs an item (non-negative integer)")
                    })?;
                    entry.enqueue(tid, item)?;
                    Ok(Json::obj(vec![("ok", Json::Bool(true))]))
                }
                "dequeue" => Ok(match entry.dequeue(tid)? {
                    Some(item) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("item", Json::num(item as f64)),
                    ]),
                    None => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("empty", Json::Bool(true)),
                    ]),
                }),
                "stats" => {
                    entry.metrics.incr("stats");
                    let mut json = entry.stats_json();
                    if let Json::Obj(map) = &mut json {
                        map.insert(
                            "registry_objects".to_string(),
                            Json::num(owner.registry.len() as f64),
                        );
                        map.insert("shard".to_string(), Json::num(owner.index as f64));
                    }
                    Ok(json)
                }
                "resize" => {
                    let width = req
                        .get("width")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| anyhow!("resize needs a width"))?;
                    let (width, previous) = entry.resize(width as usize)?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("width", Json::num(width as f64)),
                        ("previous", Json::num(previous as f64)),
                    ]))
                }
                "policy" => {
                    let spec = req
                        .get("policy")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("policy needs a policy string"))?;
                    let policy = WidthPolicy::parse(spec)
                        .ok_or_else(|| anyhow!("unknown width policy {spec:?}"))?;
                    let width = entry.set_policy(policy)?;
                    Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("policy", Json::str(policy.label())),
                        ("width", Json::num(width as f64)),
                    ]))
                }
                other => Err(anyhow!("unknown op {other:?}")),
            }
        }
    }
}

/// `list`: fan out over every shard and merge, sorted by name (map
/// iteration order must never leak into the wire protocol — it made
/// e2e assertions and cross-shard merges nondeterministic).
fn list_all(state: &ServerState) -> Json {
    let mut objects: Vec<(String, Json)> = Vec::new();
    for shard in &state.shards {
        for e in shard.registry.list() {
            objects.push((
                e.name.clone(),
                Json::obj(vec![
                    ("name", Json::str(e.name.clone())),
                    ("kind", Json::str(e.kind())),
                    ("backend", Json::str(e.backend.clone())),
                    ("shard", Json::num(shard.index as f64)),
                ]),
            ));
        }
    }
    objects.sort_by(|a, b| a.0.cmp(&b.0));
    // Server-level counters merge across shards key-wise.
    let mut server: BTreeMap<String, u64> = BTreeMap::new();
    for shard in &state.shards {
        for (k, v) in shard.metrics.snapshot() {
            *server.entry(k).or_insert(0) += v;
        }
    }
    let server: BTreeMap<String, Json> =
        server.into_iter().map(|(k, v)| (k, Json::num(v as f64))).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("count", Json::num(objects.len() as f64)),
        ("shards", Json::num(state.shards.len() as f64)),
        ("objects", Json::Arr(objects.into_iter().map(|(_, j)| j).collect())),
        ("server", Json::Obj(server)),
    ])
}

/// `snapshot` (force): drain every persisted object's journal window
/// and rewrite each shard's snapshot, truncating the WAL it absorbs.
/// An error when the server runs without persistence.
fn snapshot_all(state: &ServerState) -> Result<Json> {
    let mut snapshots = Vec::new();
    let mut any = false;
    for (i, shard) in state.shards.iter().enumerate() {
        let Some(log) = &shard.log else { continue };
        any = true;
        persist::flush_shard(state, i);
        let (objects, absorbed) = log.snapshot()?;
        shard.metrics.incr("snapshots_forced");
        snapshots.push(Json::obj(vec![
            ("shard", Json::num(shard.index as f64)),
            ("objects", Json::num(objects as f64)),
            ("wal_records_absorbed", Json::num(absorbed as f64)),
        ]));
    }
    if !any {
        return Err(anyhow!("persistence is disabled (no data_dir configured)"));
    }
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("persist", Json::Bool(true)),
        ("shards", Json::num(state.shards.len() as f64)),
        ("snapshots", Json::Arr(snapshots)),
    ]))
}

/// `stats` with `name = "*"`: the cluster aggregate — object counts,
/// funnel batch totals and per-object traffic summed over every
/// shard, plus one entry per shard with its own counters.
fn cluster_stats(state: &ServerState) -> Json {
    let mut object_count = 0usize;
    let mut agg = BatchStats::default();
    let mut totals: BTreeMap<String, u64> = BTreeMap::new();
    let mut per_shard = Vec::new();
    for shard in &state.shards {
        let entries = shard.registry.list();
        object_count += entries.len();
        for e in &entries {
            for (k, v) in e.metrics.snapshot() {
                *totals.entry(k).or_insert(0) += v;
            }
            agg.merge(&e.batch_stats());
        }
        let mut sj: BTreeMap<String, Json> = shard
            .metrics
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::num(v as f64)))
            .collect();
        sj.insert("shard".to_string(), Json::num(shard.index as f64));
        sj.insert("port".to_string(), Json::num(shard.port as f64));
        sj.insert("objects".to_string(), Json::num(entries.len() as f64));
        if let Some(log) = &shard.log {
            // Recovery-aware stats: the durability counters ride the
            // per-shard entry (`wal_replayed`/`recovered_objects`
            // land in the ordinary metrics snapshot above).
            sj.insert("persist".to_string(), Json::Bool(true));
            sj.insert("wal_records".to_string(), Json::num(log.wal_record_count() as f64));
            sj.insert("wal_flushes".to_string(), Json::num(log.wal_flush_count() as f64));
            sj.insert("wal_errors".to_string(), Json::num(log.wal_error_count() as f64));
            sj.insert("snapshots".to_string(), Json::num(log.snapshot_count() as f64));
        } else {
            sj.insert("persist".to_string(), Json::Bool(false));
        }
        per_shard.push(Json::Obj(sj));
    }
    let totals: BTreeMap<String, Json> =
        totals.into_iter().map(|(k, v)| (k, Json::num(v as f64))).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("scope", Json::str("cluster")),
        ("shards", Json::num(state.shards.len() as f64)),
        ("objects", Json::num(object_count as f64)),
        ("main_faas", Json::num(agg.main_faas as f64)),
        ("batched_ops", Json::num(agg.ops as f64)),
        ("avg_batch", Json::num(agg.avg_batch_size())),
        ("totals", Json::Obj(totals)),
        ("per_shard", Json::Arr(per_shard)),
    ])
}

/// Largest `count` one `take` request may ask for (2³²). Counters are
/// journaled and served through JSON, which is exact below 2⁵³; the
/// cap keeps a single request from vaulting a counter into the
/// inexact range (and is far beyond any sane ticket batch anyway).
pub const MAX_TAKE_COUNT: u64 = 1 << 32;

/// Client-side retry policy for capacity rejections: a rejected
/// connection never executed anything (the server writes the
/// rejection and closes without reading), so redialing is
/// idempotency-safe; the bound keeps a genuinely full shard from
/// hanging the caller.
const CAPACITY_RETRIES: u32 = 40;
const CAPACITY_RETRY_DELAY: std::time::Duration = std::time::Duration::from_millis(5);

/// True when a response is a lease-pool capacity rejection — the
/// structured `rejected` marker, with a message-text fallback.
fn is_capacity_rejection(resp: &Json) -> bool {
    resp.get("rejected").and_then(Json::as_bool) == Some(true)
        || resp
            .get("error")
            .and_then(Json::as_str)
            .is_some_and(|e| e.contains("at capacity"))
}

/// One connection to one shard.
struct ClientConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ClientConn {
    fn open(addr: &str) -> Result<ClientConn> {
        let conn = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        conn.set_nodelay(true).ok();
        let writer = conn.try_clone()?;
        Ok(ClientConn { reader: BufReader::new(conn), writer })
    }

    /// Write one request and read the matching response, skipping any
    /// pushed `greeting` lines (a sharded server greets every new
    /// connection with the shard map).
    fn roundtrip_raw(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("server closed the connection"));
            }
            let resp = Json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))?;
            if resp.get("greeting").and_then(Json::as_bool) == Some(true) {
                continue;
            }
            return Ok(resp);
        }
    }
}

/// Minimal blocking client for the registry service, shard-aware: on
/// connect it asks the server for the shard map and from then on
/// routes every named request to the owning shard's port over a
/// lazily-opened per-shard connection — the hot path never bounces
/// through a proxy shard. Un-named methods address the boot counter
/// ([`DEFAULT_OBJECT`]); `*_on` methods and the queue ops are
/// namespaced. Pre-shard (PR 3) servers are detected by their
/// "unknown op" reply to the handshake and served over the single
/// original connection.
pub struct TicketClient {
    host: String,
    ports: Vec<u16>,
    conns: Vec<Option<ClientConn>>,
}

impl TicketClient {
    pub fn connect(addr: &str) -> Result<TicketClient> {
        let (host, _) = split_host_port(addr)?;
        // Bounded retry on capacity rejections, mirroring
        // `roundtrip_on`: the handshake races lease releases of
        // just-closed connections, and a rejected connection never
        // executed anything, so redialing is safe.
        let mut attempts = 0u32;
        loop {
            let mut conn = ClientConn::open(addr)?;
            let resp =
                conn.roundtrip_raw(&Json::obj(vec![("op", Json::str("shardmap"))]))?;
            if resp.get("ok").and_then(Json::as_bool) == Some(true)
                && resp.get("shardmap").and_then(Json::as_bool) == Some(true)
            {
                let ports: Vec<u16> = resp
                    .get("ports")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("shardmap missing ports"))?
                    .iter()
                    .filter_map(Json::as_u64)
                    .map(|p| p as u16)
                    .collect();
                if ports.is_empty() {
                    return Err(anyhow!("shardmap with no ports"));
                }
                let mut conns: Vec<Option<ClientConn>> =
                    (0..ports.len()).map(|_| None).collect();
                if ports.len() == 1 {
                    // Single shard: keep the handshake connection,
                    // it is the only one we will ever need.
                    conns[0] = Some(conn);
                } else {
                    // Sharded: drop the handshake connection instead
                    // of caching it. Caching would pin one of the
                    // dialed shard's tid leases for this client's
                    // whole lifetime even if none of its objects
                    // live there — capping total clients at one
                    // shard's `workers` pool and defeating per-shard
                    // admission independence. Per-shard connections
                    // open lazily on first use.
                    drop(conn);
                }
                return Ok(TicketClient { host, ports, conns });
            }
            let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
            if err.contains("unknown op") {
                // A pre-shard server: one implicit shard on the
                // connected port, and the handshake error consumed
                // above keeps the line stream in sync.
                let port = conn.writer.peer_addr()?.port();
                return Ok(TicketClient {
                    host,
                    ports: vec![port],
                    conns: vec![Some(conn)],
                });
            }
            if is_capacity_rejection(&resp) {
                attempts += 1;
                if attempts < CAPACITY_RETRIES {
                    drop(conn);
                    std::thread::sleep(CAPACITY_RETRY_DELAY);
                    continue;
                }
            }
            return Err(anyhow!("server error: {}", if err.is_empty() { "?" } else { err }));
        }
    }

    /// Number of shards in the connected server's map.
    pub fn shards(&self) -> usize {
        self.ports.len()
    }

    /// The advertised per-shard port layout.
    pub fn shard_ports(&self) -> &[u16] {
        &self.ports
    }

    /// The shard index `name` routes to.
    pub fn shard_for(&self, name: &str) -> usize {
        shard_of(name, self.ports.len())
    }

    fn conn_for(&mut self, shard: usize) -> Result<&mut ClientConn> {
        debug_assert!(shard < self.ports.len());
        if self.conns[shard].is_none() {
            let addr = format!("{}:{}", self.host, self.ports[shard]);
            self.conns[shard] = Some(ClientConn::open(&addr)?);
        }
        Ok(self.conns[shard].as_mut().unwrap())
    }

    fn roundtrip_on(&mut self, shard: usize, req: Json) -> Result<Json> {
        // Capacity rejections can be transient: a just-closed
        // connection's lease is only released once its handler
        // observes the EOF, so a freshly-dialed connection can race
        // the release. Retry them within the shared policy bound.
        let mut attempts = 0u32;
        loop {
            let resp = match self.conn_for(shard)?.roundtrip_raw(&req) {
                Ok(resp) => resp,
                Err(e) => {
                    // Transport failure (closed socket, bad line):
                    // drop the cached connection so the next request
                    // to this shard reconnects instead of reusing a
                    // dead socket. Not retried here — the request may
                    // already have executed server-side.
                    self.conns[shard] = None;
                    return Err(e);
                }
            };
            if resp.get("ok").and_then(Json::as_bool) != Some(true) {
                if is_capacity_rejection(&resp) {
                    // The server closes after a capacity rejection;
                    // evict the dead cached connection either way.
                    self.conns[shard] = None;
                    attempts += 1;
                    if attempts < CAPACITY_RETRIES {
                        std::thread::sleep(CAPACITY_RETRY_DELAY);
                        continue;
                    }
                }
                return Err(anyhow!(
                    "server error: {}",
                    resp.get("error").and_then(Json::as_str).unwrap_or("?")
                ));
            }
            return Ok(resp);
        }
    }

    /// Route a named request to its owning shard.
    fn roundtrip(&mut self, name: &str, req: Json) -> Result<Json> {
        self.roundtrip_on(self.shard_for(name), req)
    }

    /// Create a named object (`kind`: `counter` | `queue`; `backend`:
    /// the spec grammar, empty for the kind's default).
    pub fn create(&mut self, name: &str, kind: &str, backend: &str) -> Result<()> {
        self.create_with(name, kind, backend, None, None, true)
    }

    /// `create` with the optional per-object overrides: elastic slot
    /// capacity, the §4.4 direct-thread quota (counters only), and
    /// the durability opt-out (`persist = false` keeps the object
    /// ephemeral on a persistent server).
    pub fn create_with(
        &mut self,
        name: &str,
        kind: &str,
        backend: &str,
        max_width: Option<u64>,
        direct_quota: Option<u64>,
        persist: bool,
    ) -> Result<()> {
        let mut pairs = vec![
            ("op", Json::str("create")),
            ("name", Json::str(name)),
            ("kind", Json::str(kind)),
        ];
        if !backend.is_empty() {
            pairs.push(("backend", Json::str(backend)));
        }
        if let Some(w) = max_width {
            pairs.push(("max_width", Json::num(w as f64)));
        }
        if let Some(d) = direct_quota {
            pairs.push(("direct_quota", Json::num(d as f64)));
        }
        if !persist {
            pairs.push(("persist", Json::Bool(false)));
        }
        self.roundtrip(name, Json::obj(pairs)).map(drop)
    }

    /// Force a snapshot on every persistent shard: the pending
    /// journal windows are flushed, each shard's snapshot is
    /// rewritten, and the WAL it absorbs is truncated. Errors when
    /// the server runs without a `data_dir`.
    pub fn snapshot(&mut self) -> Result<Json> {
        self.roundtrip_on(0, Json::obj(vec![("op", Json::str("snapshot"))]))
    }

    /// Delete a named object.
    pub fn delete(&mut self, name: &str) -> Result<()> {
        self.roundtrip(
            name,
            Json::obj(vec![("op", Json::str("delete")), ("name", Json::str(name))]),
        )
        .map(drop)
    }

    /// List registered objects across all shards, sorted by name, as
    /// `(name, kind, backend)` triples.
    pub fn list(&mut self) -> Result<Vec<(String, String, String)>> {
        let resp = self.roundtrip_on(0, Json::obj(vec![("op", Json::str("list"))]))?;
        let objects = resp
            .get("objects")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing objects"))?;
        objects
            .iter()
            .map(|o| {
                let field = |k: &str| {
                    o.get(k)
                        .and_then(Json::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("object missing {k}"))
                };
                Ok((field("name")?, field("kind")?, field("backend")?))
            })
            .collect()
    }

    /// Enqueue `item` on a named queue.
    pub fn enqueue(&mut self, name: &str, item: u64) -> Result<()> {
        self.roundtrip(
            name,
            Json::obj(vec![
                ("op", Json::str("enqueue")),
                ("name", Json::str(name)),
                ("item", Json::num(item as f64)),
            ]),
        )
        .map(drop)
    }

    /// Dequeue from a named queue (`None` when empty).
    pub fn dequeue(&mut self, name: &str) -> Result<Option<u64>> {
        let resp = self.roundtrip(
            name,
            Json::obj(vec![("op", Json::str("dequeue")), ("name", Json::str(name))]),
        )?;
        if resp.get("empty").and_then(Json::as_bool) == Some(true) {
            return Ok(None);
        }
        resp.get("item")
            .and_then(Json::as_u64)
            .map(Some)
            .ok_or_else(|| anyhow!("missing item"))
    }

    /// Take a contiguous range of `count` values from a named counter.
    pub fn take_on(&mut self, name: &str, count: u64, priority: bool) -> Result<u64> {
        let mut pairs = vec![
            ("op", Json::str("take")),
            ("name", Json::str(name)),
            ("count", Json::num(count as f64)),
        ];
        if priority {
            pairs.push(("priority", Json::Bool(true)));
        }
        let resp = self.roundtrip(name, Json::obj(pairs))?;
        resp.get("start").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing start"))
    }

    /// Take from the default counter; returns the range start.
    pub fn take(&mut self, count: u64, priority: bool) -> Result<u64> {
        self.take_on(DEFAULT_OBJECT, count, priority)
    }

    /// Read a named counter.
    pub fn read_on(&mut self, name: &str) -> Result<u64> {
        let resp = self.roundtrip(
            name,
            Json::obj(vec![("op", Json::str("read")), ("name", Json::str(name))]),
        )?;
        resp.get("value").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing value"))
    }

    pub fn read(&mut self) -> Result<u64> {
        self.read_on(DEFAULT_OBJECT)
    }

    /// Per-object stats for a named object.
    pub fn stats_on(&mut self, name: &str) -> Result<Json> {
        self.roundtrip(
            name,
            Json::obj(vec![("op", Json::str("stats")), ("name", Json::str(name))]),
        )
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.stats_on(DEFAULT_OBJECT)
    }

    /// The cluster aggregate (`stats` with `name = "*"`): objects,
    /// funnel batch totals and traffic merged over every shard.
    pub fn cluster_stats(&mut self) -> Result<Json> {
        self.roundtrip_on(
            0,
            Json::obj(vec![("op", Json::str("stats")), ("name", Json::str("*"))]),
        )
    }

    /// Set a named object's active width; returns the width in force.
    pub fn resize_on(&mut self, name: &str, width: u64) -> Result<u64> {
        let resp = self.roundtrip(
            name,
            Json::obj(vec![
                ("op", Json::str("resize")),
                ("name", Json::str(name)),
                ("width", Json::num(width as f64)),
            ]),
        )?;
        resp.get("width").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing width"))
    }

    pub fn resize(&mut self, width: u64) -> Result<u64> {
        self.resize_on(DEFAULT_OBJECT, width)
    }

    /// Swap a named object's width policy (`fixed:<m>`, `sqrtp`,
    /// `aimd`).
    pub fn set_policy_on(&mut self, name: &str, policy: &str) -> Result<String> {
        let resp = self.roundtrip(
            name,
            Json::obj(vec![
                ("op", Json::str("policy")),
                ("name", Json::str(name)),
                ("policy", Json::str(policy)),
            ]),
        )?;
        resp.get("policy")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("missing policy"))
    }

    pub fn set_policy(&mut self, policy: &str) -> Result<String> {
        self.set_policy_on(DEFAULT_OBJECT, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start() -> ServerHandle {
        serve(&ServeOpts::fixed("127.0.0.1:0", 3, 2)).unwrap()
    }

    #[test]
    fn tickets_are_disjoint_ranges() {
        let server = start();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = TicketClient::connect(&addr).unwrap();
                    let mut ranges = Vec::new();
                    for i in 0..50u64 {
                        let count = 1 + i % 4;
                        let start = c.take(count, i % 7 == 0).unwrap();
                        ranges.push((start, count));
                    }
                    ranges
                })
            })
            .collect();
        let mut all: Vec<(u64, u64)> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        // Ranges must tile [0, total) without overlap.
        let mut expected_start = 0u64;
        for (start, count) in all {
            assert_eq!(start, expected_start, "overlapping or gapped ticket ranges");
            expected_start = start + count;
        }
        server.shutdown();
    }

    #[test]
    fn read_and_stats_work() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.take(5, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 5);
        let stats = c.stats().unwrap();
        assert!(stats.get("take").and_then(Json::as_u64).unwrap_or(0) >= 1);
        assert_eq!(stats.get("name").and_then(Json::as_str), Some(DEFAULT_OBJECT));
        assert_eq!(stats.get("registry_objects").and_then(Json::as_u64), Some(1));
        server.shutdown();
    }

    #[test]
    fn single_shard_shardmap_op_and_no_greeting() {
        use std::io::{BufRead, Write};
        let server = start();
        // Raw socket: a single-shard server must not greet (that is
        // the PR 3 wire contract), but must answer the shardmap op.
        let conn = std::net::TcpStream::connect(server.addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = std::io::BufReader::new(conn);
        writer.write_all(b"{\"op\":\"take\",\"count\":1}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("start").and_then(Json::as_u64),
            Some(0),
            "first line is the take response, not a greeting: {line}"
        );
        writer.write_all(b"{\"op\":\"shardmap\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("shards").and_then(Json::as_u64), Some(1));
        assert_eq!(resp.get("hash").and_then(Json::as_str), Some(SHARD_HASH_SCHEME));
        let ports = resp.get("ports").and_then(Json::as_arr).unwrap();
        assert_eq!(ports.len(), 1);
        assert_eq!(ports[0].as_u64(), Some(server.addr.port() as u64));
        server.shutdown();
    }

    #[test]
    fn sharded_server_greets_and_routes() {
        let server = serve(&ServeOpts::sharded("127.0.0.1:0", 3, 2, 2)).unwrap();
        assert_eq!(server.shard_ports().len(), 3);
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.shards(), 3);
        assert_eq!(c.shard_ports(), server.shard_ports());
        // The default counter works regardless of which shard owns it.
        assert_eq!(c.take(2, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 2);
        // Named objects land on their hash shard and round-trip.
        for name in ["a", "b", "c", "d", "e"] {
            c.create(name, "counter", "elastic:fixed:1").unwrap();
            assert_eq!(c.take_on(name, 1, false).unwrap(), 0);
        }
        let listed = c.list().unwrap();
        let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c", "d", "e", DEFAULT_OBJECT], "sorted merge");
        // The cluster aggregate sees every shard's objects.
        let agg = c.cluster_stats().unwrap();
        assert_eq!(agg.get("objects").and_then(Json::as_u64), Some(6));
        assert_eq!(agg.get("shards").and_then(Json::as_u64), Some(3));
        assert_eq!(
            agg.get("per_shard").and_then(Json::as_arr).map(|a| a.len()),
            Some(3)
        );
        server.shutdown();
    }

    #[test]
    fn legacy_connection_to_sharded_server_is_forwarded() {
        use std::io::{BufRead, Write};
        let server = serve(&ServeOpts::sharded("127.0.0.1:0", 2, 2, 2)).unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create("roam", "counter", "elastic:fixed:1").unwrap();
        // A client that ignores the shard map and sends everything to
        // one port must still be served correctly (in-process
        // forwarding), for every shard's port.
        for port in server.shard_ports() {
            let conn = std::net::TcpStream::connect(("127.0.0.1", *port)).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = std::io::BufReader::new(conn);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap(); // greeting
            assert_eq!(
                Json::parse(&line).unwrap().get("greeting").and_then(Json::as_bool),
                Some(true)
            );
            writer.write_all(b"{\"op\":\"take\",\"name\":\"roam\",\"count\":1}\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{line}");
        }
        assert_eq!(c.read_on("roam").unwrap(), 2, "both forwarded takes counted");
        server.shutdown();
    }

    #[test]
    fn resize_and_policy_ops_reconfigure_live() {
        let server = serve(&ServeOpts {
            max_aggregators: 8,
            resize_interval_ms: 0, // manual control only
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.resize(5).unwrap(), 5);
        assert_eq!(c.resize(100).unwrap(), 8, "clamped to capacity");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(8));
        assert_eq!(stats.get("max_width").and_then(Json::as_u64), Some(8));
        assert!(stats.get("resizes").and_then(Json::as_u64).unwrap_or(0) >= 2);
        // Policy swap applies immediately (fixed:3 forces the width).
        assert_eq!(c.set_policy("fixed:3").unwrap(), "fixed-3");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(3));
        assert!(c.set_policy("bogus").is_err());
        // Tickets still flow after reconfiguration.
        assert_eq!(c.take(2, false).unwrap(), 0);
        assert_eq!(c.read().unwrap(), 2);
        server.shutdown();
    }

    #[test]
    fn stats_expose_contention_counters() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        for _ in 0..20 {
            c.take(1, false).unwrap();
        }
        let stats = c.stats().unwrap();
        let ops = stats.get("batched_ops").and_then(Json::as_u64).unwrap();
        let faas = stats.get("main_faas").and_then(Json::as_u64).unwrap();
        assert!(ops >= 20);
        assert!(faas <= ops, "ops ({ops}) must bound main F&As ({faas})");
        assert!(stats.get("avg_batch").is_some());
        assert_eq!(stats.get("width_policy").and_then(Json::as_str), Some("fixed-2"));
        server.shutdown();
    }

    #[test]
    fn direct_quota_over_the_wire() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create_with("vip", "counter", "elastic:fixed:2", None, Some(0), true).unwrap();
        assert_eq!(c.take_on("vip", 4, true).unwrap(), 0);
        let stats = c.stats_on("vip").unwrap();
        assert_eq!(stats.get("direct_quota").and_then(Json::as_u64), Some(0));
        assert_eq!(
            stats.get("take_priority_demoted").and_then(Json::as_u64),
            Some(1),
            "quota 0 demotes priority to the funnel"
        );
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("elastic:fixed:2:d0"));
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        use std::io::{BufRead, Write};
        let server = start();
        let conn = std::net::TcpStream::connect(server.addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = std::io::BufReader::new(conn);
        writer.write_all(b"{\"op\":\"nope\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        // Connection stays usable.
        writer.write_all(b"{\"op\":\"take\",\"count\":1}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("start").and_then(Json::as_u64), Some(0));
        server.shutdown();
    }

    #[test]
    fn registry_ops_over_the_wire() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create("jobs", "queue", "lcrq+elastic:fixed:2").unwrap();
        c.create("orders", "counter", "").unwrap(); // kind default backend
        assert!(c.create("jobs", "queue", "").is_err(), "duplicate name");
        let listed = c.list().unwrap();
        let names: Vec<&str> = listed.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, vec!["jobs", "orders", DEFAULT_OBJECT]);
        assert_eq!(listed[0].1, "queue");
        assert_eq!(listed[0].2, "lcrq+elastic:fixed:2");

        // Queue traffic, independent of the default counter.
        assert_eq!(c.dequeue("jobs").unwrap(), None);
        c.enqueue("jobs", 41).unwrap();
        c.enqueue("jobs", 42).unwrap();
        assert_eq!(c.dequeue("jobs").unwrap(), Some(41));
        // Named counter traffic.
        assert_eq!(c.take_on("orders", 3, false).unwrap(), 0);
        assert_eq!(c.read_on("orders").unwrap(), 3);
        assert_eq!(c.read().unwrap(), 0, "default counter untouched");

        // Kind mismatches and unknown names are clean errors.
        assert!(c.take_on("jobs", 1, false).is_err());
        assert!(c.enqueue(DEFAULT_OBJECT, 1).is_err());
        assert!(c.dequeue("ghost").is_err());

        // Per-object stats are independent.
        let jobs = c.stats_on("jobs").unwrap();
        assert_eq!(jobs.get("kind").and_then(Json::as_str), Some("queue"));
        assert_eq!(jobs.get("enqueue").and_then(Json::as_u64), Some(2));
        assert_eq!(jobs.get("active_width").and_then(Json::as_u64), Some(2));
        let orders = c.stats_on("orders").unwrap();
        assert_eq!(orders.get("take").and_then(Json::as_u64), Some(1));
        assert!(orders.get("enqueue").is_none());

        c.delete("jobs").unwrap();
        assert!(c.delete("jobs").is_err());
        assert_eq!(c.list().unwrap().len(), 2);
        server.shutdown();
    }

    #[test]
    fn queue_width_ops_ride_the_index_factory() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create("q", "queue", "lcrq+elastic:fixed:2").unwrap();
        assert_eq!(c.resize_on("q", 4).unwrap(), 4);
        assert_eq!(c.set_policy_on("q", "fixed:1").unwrap(), "fixed-1");
        let stats = c.stats_on("q").unwrap();
        assert_eq!(stats.get("active_width").and_then(Json::as_u64), Some(1));
        // Non-elastic indices have no width controls.
        c.create("q2", "queue", "lcrq+hw").unwrap();
        assert!(c.resize_on("q2", 4).is_err());
        server.shutdown();
    }

    #[test]
    fn connections_beyond_lease_pool_rejected() {
        let server = serve(&ServeOpts::fixed("127.0.0.1:0", 1, 2)).unwrap();
        let addr = server.addr.to_string();
        let mut first = TicketClient::connect(&addr).unwrap();
        // Completing a request proves the only lease is held.
        assert_eq!(first.take(1, false).unwrap(), 0);
        // Read the rejection line without writing first (a write could
        // race the server-side close into an RST that drops the line).
        let second = TcpStream::connect(&addr).unwrap();
        let mut line = String::new();
        BufReader::new(second).read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("capacity"), "unexpected rejection: {err}");
        // The leased connection keeps working.
        assert_eq!(first.take(1, false).unwrap(), 1);
        server.shutdown();
    }

    #[test]
    fn manifest_objects_precreated_at_boot() {
        let server = serve(&ServeOpts {
            objects: vec![
                ObjectManifest::new("jobs", "queue", "lcrq+elastic"),
                ObjectManifest::new("orders", "counter", "elastic:sqrtp"),
            ],
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.list().unwrap().len(), 3);
        c.enqueue("jobs", 9).unwrap();
        assert_eq!(c.dequeue("jobs").unwrap(), Some(9));
        assert_eq!(c.take_on("orders", 2, false).unwrap(), 0);
        server.shutdown();
        // A manifest colliding with the boot counter fails loudly.
        let err = serve(&ServeOpts {
            objects: vec![ObjectManifest::new(DEFAULT_OBJECT, "counter", "elastic:aimd")],
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        });
        assert!(err.is_err());
    }

    #[test]
    fn snapshot_op_requires_persistence() {
        let server = start();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        let err = c.snapshot().unwrap_err();
        assert!(err.to_string().contains("persistence"), "{err}");
        server.shutdown();
    }

    #[test]
    fn snapshot_op_flushes_and_compacts() {
        let dir = crate::util::scratch_dir("snap-op");
        let server = serve(&ServeOpts {
            // Long group-commit interval: only the snapshot op (or
            // shutdown) will flush within the test's lifetime.
            persist: Some(PersistOpts {
                data_dir: dir.to_string_lossy().into_owned(),
                fsync_interval_ms: 60_000,
                snapshot_interval_ms: 0,
            }),
            ..ServeOpts::fixed("127.0.0.1:0", 3, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.take(7, false).unwrap();
        let resp = c.snapshot().unwrap();
        assert_eq!(resp.get("persist").and_then(Json::as_bool), Some(true));
        let snaps = resp.get("snapshots").and_then(Json::as_arr).unwrap();
        assert_eq!(snaps.len(), 1);
        assert!(
            snaps[0].get("wal_records_absorbed").and_then(Json::as_u64).unwrap() >= 1,
            "the pending counter window must be flushed into the snapshot"
        );
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("persist").and_then(Json::as_bool), Some(true));
        // Even a crash after the forced snapshot keeps the state.
        server.crash();
        let server = serve(&ServeOpts {
            persist: Some(PersistOpts::dir(dir.to_string_lossy().into_owned())),
            ..ServeOpts::fixed("127.0.0.1:0", 3, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.read().unwrap(), 7, "forced snapshot survived the crash");
        server.shutdown();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn forwarded_ops_beyond_foreign_pool_complete() {
        use std::io::{BufRead, Write};
        // More concurrent mis-routed clients than FOREIGN_TIDS: the
        // per-op foreign leases must serialize them, not break them.
        let server = serve(&ServeOpts::sharded("127.0.0.1:0", 2, 8, 2)).unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        c.create("roam", "counter", "elastic:fixed:1").unwrap();
        let wrong_port = server.shard_ports()[1 - c.shard_for("roam")];
        let clients = FOREIGN_TIDS + 3;
        let per_client = 40u64;
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                std::thread::spawn(move || {
                    let conn =
                        std::net::TcpStream::connect(("127.0.0.1", wrong_port)).unwrap();
                    let mut writer = conn.try_clone().unwrap();
                    let mut reader = std::io::BufReader::new(conn);
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap(); // greeting
                    for _ in 0..per_client {
                        writer
                            .write_all(b"{\"op\":\"take\",\"name\":\"roam\",\"count\":1}\n")
                            .unwrap();
                        line.clear();
                        reader.read_line(&mut line).unwrap();
                        let resp = Json::parse(&line).unwrap();
                        assert_eq!(
                            resp.get("ok").and_then(Json::as_bool),
                            Some(true),
                            "{line}"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            c.read_on("roam").unwrap(),
            clients as u64 * per_client,
            "every forwarded take must land exactly once"
        );
        server.shutdown();
    }

    #[test]
    fn manifest_direct_quota_applies() {
        let server = serve(&ServeOpts {
            objects: vec![ObjectManifest {
                direct_quota: Some(1),
                ..ObjectManifest::new("vip", "counter", "elastic:fixed:2")
            }],
            ..ServeOpts::fixed("127.0.0.1:0", 2, 2)
        })
        .unwrap();
        let mut c = TicketClient::connect(&server.addr.to_string()).unwrap();
        let stats = c.stats_on("vip").unwrap();
        assert_eq!(stats.get("direct_quota").and_then(Json::as_u64), Some(1));
        assert_eq!(stats.get("backend").and_then(Json::as_str), Some("elastic:fixed:2:d1"));
        server.shutdown();
    }
}
